//! Model persistence: a dependency-free, versioned JSON checkpoint codec.
//!
//! The paper's Quantization Observer keeps per-leaf monitoring state tiny
//! and O(1) per slot (PAPER.md Sec. 4) — which is exactly what makes
//! whole-model checkpoints cheap: a QO-backed tree serializes its
//! complete split-monitoring state in |H| slots per leaf where an E-BST
//! checkpoint carries one node per distinct observed value (the
//! `bench_suite::serve_bench` scenario prints the size gap).
//!
//! ## Contract
//!
//! `save → load` is **bit-for-bit invisible**: the restored model returns
//! bit-identical predictions *and* continues training along the identical
//! trajectory (same PRNG draws, same split decisions, same detector
//! firings). Everything stateful travels in the checkpoint — node arenas,
//! observer hash slots and warmup buffers, leaf linear models, ADWIN
//! histograms, per-member PRNG words, deferred-attempt queues. Engines
//! that are *not* model state (split backends, thread pools) are
//! re-instantiated from the restored options. The property is enforced
//! end-to-end by `rust/tests/persist_roundtrip.rs` across model kinds ×
//! observer kinds × random streams.
//!
//! Exactness rests on two encoding rules ([`codec`]): integers beyond
//! f64's 53-bit mantissa travel as decimal strings, and finite floats
//! travel through Rust's shortest-round-trip `Display` (non-finite ones
//! as tagged strings).
//!
//! The structural invariants a checkpoint must satisfy (arena topology,
//! QO slot tables, delta hash chains, …) are cataloged in
//! `docs/INVARIANTS.md` and re-checked *independently of the decoders*
//! by [`crate::audit::invariants`]; debug builds run that verifier at
//! [`Model::load`], and `rust/tests/audit_corruption.rs` proves every
//! single-field corruption is caught with its rule id.
//!
//! ## Format
//!
//! ```json
//! {"format": "qostream-checkpoint", "version": 1,
//!  "kind": "tree" | "arf" | "bagging",
//!  "model": { …kind-specific payload… }}
//! ```
//!
//! Key order is canonical (the writer sorts), so encode → decode →
//! encode reproduces the exact same text — which is what lets the serve
//! layer treat a checkpoint string as a content-addressable snapshot.
//!
//! Beside the canonical text there is a compact **binary** fast path
//! ([`binary`]): the same document in a length-prefixed envelope whose
//! `doc_hash` equals the canonical text's, so the two formats are
//! interchangeable and mutually verifiable — layout and negotiation
//! rules in `docs/FORMATS.md`. [`Model::load`] sniffs the leading magic
//! bytes and accepts either.

pub mod binary;
pub mod codec;
pub mod delta;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::common::json::Json;
use crate::eval::Regressor;
use crate::forest::{ArfRegressor, OnlineBaggingRegressor};
use crate::tree::HoeffdingTreeRegressor;

use codec::{field, pstr, pu64};

/// Checkpoint format marker.
pub const FORMAT: &str = "qostream-checkpoint";
/// Current checkpoint version (bumped on incompatible layout changes).
pub const VERSION: u64 = 1;

/// A checkpointable model: every kind the CLI and the serve layer can
/// train. Implements [`Regressor`] by delegation, so the prequential
/// harness and the server drive all kinds uniformly.
///
/// `Clone` is a *structural* clone: node arenas are copied but leaf
/// state is shared behind `Arc` and copy-on-written by whichever side
/// trains next, so cloning costs O(nodes) pointer work — not a codec
/// round-trip. This is the serve layer's snapshot hot-swap primitive
/// (see `docs/FORMATS.md`); [`Model::clone_via_codec`] remains as the
/// slow path the CLI uses to prove checkpoint bit-identity.
#[derive(Clone)]
pub enum Model {
    Tree(HoeffdingTreeRegressor),
    Arf(ArfRegressor),
    Bagging(OnlineBaggingRegressor),
}

impl Model {
    /// The checkpoint `kind` tag.
    pub fn kind(&self) -> &'static str {
        match self {
            Model::Tree(_) => "tree",
            Model::Arf(_) => "arf",
            Model::Bagging(_) => "bagging",
        }
    }

    /// Input dimensionality (request validation in [`crate::serve`]).
    pub fn n_features(&self) -> usize {
        match self {
            Model::Tree(t) => t.n_features(),
            Model::Arf(f) => f.n_features(),
            Model::Bagging(b) => b.n_features(),
        }
    }

    /// Encode into a versioned checkpoint document.
    pub fn to_checkpoint(&self) -> Result<Json> {
        let payload = match self {
            Model::Tree(t) => t.to_json()?,
            Model::Arf(f) => f.to_json()?,
            Model::Bagging(b) => b.to_json()?,
        };
        let mut o = Json::obj();
        o.set("format", FORMAT)
            .set("version", codec::ju64(VERSION))
            .set("kind", self.kind())
            .set("model", payload);
        Ok(o)
    }

    /// Decode a checkpoint document written by [`Model::to_checkpoint`].
    pub fn from_checkpoint(j: &Json) -> Result<Model> {
        let format = pstr(field(j, "format")?, "format")?;
        if format != FORMAT {
            return Err(anyhow!("not a qostream checkpoint (format {format:?})"));
        }
        let version = pu64(field(j, "version")?, "version")?;
        if version != VERSION {
            return Err(anyhow!(
                "checkpoint version {version} unsupported (this build reads {VERSION})"
            ));
        }
        let model = field(j, "model")?;
        match pstr(field(j, "kind")?, "kind")? {
            "tree" => Ok(Model::Tree(HoeffdingTreeRegressor::from_json(model)?)),
            "arf" => Ok(Model::Arf(ArfRegressor::from_json(model)?)),
            "bagging" => Ok(Model::Bagging(OnlineBaggingRegressor::from_json(model)?)),
            other => Err(anyhow!("unknown model kind {other:?}")),
        }
    }

    /// Encode to the canonical compact checkpoint text.
    pub fn to_text(&self) -> Result<String> {
        Ok(self.to_checkpoint()?.to_compact())
    }

    /// Decode from checkpoint text ([`Model::to_text`] or a saved file).
    pub fn from_text(text: &str) -> Result<Model> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        Model::from_checkpoint(&j)
    }

    /// Write the checkpoint to a file (compact text plus a trailing
    /// newline, so the file is itself one NDJSON record).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut text = self.to_text()?;
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("writing checkpoint {}", path.display()))?;
        Ok(())
    }

    /// Encode into the binary checkpoint envelope ([`binary`]): the same
    /// canonical document, length-prefixed with hashes — the disk + wire
    /// fast path (`docs/FORMATS.md`).
    pub fn to_binary(&self) -> Result<Vec<u8>> {
        Ok(binary::encode_doc(&self.to_checkpoint()?))
    }

    /// Decode a binary checkpoint ([`Model::to_binary`]). Envelope,
    /// trailer hash and canonical `doc_hash` are all verified; debug
    /// builds additionally audit the decoded document like
    /// [`Model::load`] does.
    pub fn from_binary(bytes: &[u8]) -> Result<Model> {
        let doc = binary::decode_doc(bytes)?;
        #[cfg(debug_assertions)]
        {
            if let Some(cause) = crate::audit::invariants::explain(&doc) {
                return Err(anyhow!(
                    "binary checkpoint fails audit: {cause} (see docs/INVARIANTS.md)"
                ));
            }
        }
        Model::from_checkpoint(&doc)
    }

    /// Write the checkpoint in the binary envelope format.
    pub fn save_binary(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_binary()?)
            .with_context(|| format!("writing binary checkpoint {}", path.display()))?;
        Ok(())
    }

    /// Load a checkpoint file written by [`Model::save`] or
    /// [`Model::save_binary`] — the leading magic bytes select the
    /// decoder, so callers never need to know which format a file is in.
    ///
    /// Debug builds audit the document against the invariant catalog
    /// (`docs/INVARIANTS.md`) *before* decoding: a corrupted file fails
    /// loudly with the broken rule named, never silently loads. Release
    /// builds skip the audit (the decoders keep their own hard checks);
    /// `qostream audit --checkpoint FILE` runs it on demand.
    pub fn load(path: impl AsRef<Path>) -> Result<Model> {
        let path = path.as_ref();
        let raw = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let doc = if binary::is_binary(&raw) {
            binary::decode_doc(&raw)
                .map_err(|e| e.context(format!("decoding binary checkpoint {}", path.display())))?
        } else {
            let text = std::str::from_utf8(&raw)
                .map_err(|e| anyhow!("checkpoint {} is not UTF-8: {e}", path.display()))?;
            Json::parse(text.trim_end())
                .map_err(|e| anyhow!("decoding checkpoint {}: {e}", path.display()))?
        };
        #[cfg(debug_assertions)]
        {
            if let Some(cause) = crate::audit::invariants::explain(&doc) {
                return Err(anyhow!(
                    "checkpoint {} fails audit: {cause} (see docs/INVARIANTS.md)",
                    path.display()
                ));
            }
        }
        Model::from_checkpoint(&doc)
            .map_err(|e| e.context(format!("decoding checkpoint {}", path.display())))
    }

    /// Deep-copy through the codec. This is how the serve layer publishes
    /// read-only snapshots: the round-trip *is* the clone, so every
    /// published snapshot doubles as a proof the codec preserved the
    /// model it came from.
    pub fn clone_via_codec(&self) -> Result<Model> {
        Model::from_text(&self.to_text()?)
    }

    /// Resident heap footprint of the model in bytes (capacity-based:
    /// node arenas, observer slot tables/arenas, leaf linear models).
    /// Surfaced as the `qostream_model_mem_bytes` gauge and in the serve
    /// `stats` response — the precursor to memory-governed serving.
    pub fn mem_bytes(&self) -> usize {
        match self {
            Model::Tree(t) => t.mem_bytes(),
            Model::Arf(f) => f.mem_bytes(),
            Model::Bagging(b) => b.mem_bytes(),
        }
    }

    /// Instances absorbed since the last [`Model::mark_synced`]. The
    /// serve layer's publisher marks the model synced on every real
    /// publication and uses a zero here as proof that the replication
    /// log's document still equals the live model — skipping the whole
    /// encode → decode → diff round-trip for no-op snapshots.
    pub fn learns_since_sync(&self) -> u64 {
        match self {
            Model::Tree(t) => t.learns_since_sync(),
            Model::Arf(f) => f.learns_since_sync(),
            Model::Bagging(b) => b.learns_since_sync(),
        }
    }

    /// Reset the touched-state counters after publishing a
    /// snapshot/delta of this model.
    pub fn mark_synced(&mut self) {
        match self {
            Model::Tree(t) => t.mark_synced(),
            Model::Arf(f) => f.mark_synced(),
            Model::Bagging(b) => b.mark_synced(),
        }
    }
}

impl Regressor for Model {
    fn predict(&self, x: &[f64]) -> f64 {
        match self {
            Model::Tree(t) => t.predict(x),
            Model::Arf(f) => f.predict(x),
            Model::Bagging(b) => b.predict(x),
        }
    }

    fn learn_one(&mut self, x: &[f64], y: f64) {
        match self {
            Model::Tree(t) => t.learn_one(x, y),
            Model::Arf(f) => f.learn_one(x, y),
            Model::Bagging(b) => b.learn_one(x, y),
        }
    }

    fn name(&self) -> String {
        match self {
            Model::Tree(t) => t.name(),
            Model::Arf(f) => f.name(),
            Model::Bagging(b) => b.name(),
        }
    }

    fn n_elements(&self) -> usize {
        match self {
            Model::Tree(t) => t.n_elements(),
            Model::Arf(f) => f.n_elements(),
            Model::Bagging(b) => b.n_elements(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::ArfOptions;
    use crate::observer::{factory, QuantizationObserver, RadiusPolicy};
    use crate::stream::{Friedman1, Stream};
    use crate::tree::HtrOptions;

    fn qo_factory() -> Box<dyn crate::observer::ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    fn trained_tree(n: usize) -> Model {
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory());
        let mut stream = Friedman1::new(3, 1.0);
        for _ in 0..n {
            let inst = stream.next_instance().unwrap();
            tree.learn_one(&inst.x, inst.y);
        }
        Model::Tree(tree)
    }

    #[test]
    fn checkpoint_text_is_canonical() {
        let model = trained_tree(2000);
        let text = model.to_text().unwrap();
        let reencoded = Model::from_text(&text).unwrap().to_text().unwrap();
        assert_eq!(text, reencoded, "encode → decode → encode must be a fixpoint");
    }

    #[test]
    fn clone_via_codec_predicts_identically() {
        let model = trained_tree(3000);
        let clone = model.clone_via_codec().unwrap();
        let mut probe = Friedman1::new(9, 0.0);
        for _ in 0..50 {
            let inst = probe.next_instance().unwrap();
            assert_eq!(model.predict(&inst.x).to_bits(), clone.predict(&inst.x).to_bits());
        }
    }

    #[test]
    fn save_load_roundtrips_through_a_file() {
        let model = trained_tree(1000);
        let path = std::env::temp_dir()
            .join(format!("qostream-ckpt-test-{}.json", std::process::id()));
        model.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.kind(), "tree");
        assert_eq!(back.name(), model.name());
        assert_eq!(back.predict(&[0.5; 10]).to_bits(), model.predict(&[0.5; 10]).to_bits());
    }

    #[test]
    fn version_and_format_are_enforced() {
        let model = trained_tree(100);
        let mut j = model.to_checkpoint().unwrap();
        j.set("version", codec::ju64(99));
        assert!(Model::from_checkpoint(&j).is_err());
        let mut j = model.to_checkpoint().unwrap();
        j.set("format", "something-else");
        assert!(Model::from_checkpoint(&j).is_err());
        assert!(Model::from_text("{}").is_err());
        assert!(Model::from_text("not json").is_err());
    }

    #[test]
    fn arf_checkpoint_kind_roundtrips() {
        let mut arf = ArfRegressor::new(
            10,
            ArfOptions { n_members: 2, lambda: 2.0, seed: 5, ..Default::default() },
            qo_factory(),
        );
        let mut stream = Friedman1::new(7, 1.0);
        for _ in 0..1200 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        let model = Model::Arf(arf);
        let back = Model::from_text(&model.to_text().unwrap()).unwrap();
        assert_eq!(back.kind(), "arf");
        assert_eq!(back.predict(&[0.4; 10]).to_bits(), model.predict(&[0.4; 10]).to_bits());
    }
}
