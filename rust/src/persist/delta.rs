//! Delta checkpoints: exact structural diffs between canonical
//! checkpoint documents, plus the versioned [`DeltaLog`] the replication
//! layer ([`crate::serve::replicate`]) publishes from.
//!
//! ## Why diffs are exact here
//!
//! The checkpoint codec ([`super`]) writes **canonical** text: object keys
//! are sorted, floats print their shortest round-trip representation, and
//! encode → decode → encode is a byte-for-byte fixpoint. The paper's slot
//! tables and `VarStats` are mergeable/subtractable O(1) summaries
//! (PAPER.md Sec. 3–4), so the state a learn touches is a handful of
//! localized slots — which means two consecutive checkpoints differ in a
//! few small subtrees (the touched leaves' observers, the routed path's
//! counters, the PRNG words) while the rest of the document is identical.
//! A structural diff therefore *is* the touched-state extraction: it
//! recurses only where subtrees differ and emits exactly the changed
//! values. `apply(base, diff(base, next)) == next` **structurally**, and
//! because the text form is canonical, also **byte-for-byte** (the
//! property `rust/tests/persist_roundtrip.rs` asserts across model ×
//! observer kinds).
//!
//! ## Patch format
//!
//! A patch is a JSON array of ops, applied in order:
//!
//! * `{"p": [..path..], "v": value}` — set: replace the value at the path
//!   (for arrays, an index equal to the current length appends).
//! * `{"p": [..path..], "d": true}` — delete the object key at the path.
//! * `{"p": [..path..], "n": len}` — truncate the array at the path.
//!
//! Path segments are object keys (strings) or array indices (numbers).
//! Ops are emitted depth-first in deterministic order (truncations before
//! element edits, appends in increasing index order), so applying them
//! sequentially is always well-defined.
//!
//! ## Versioning
//!
//! [`DeltaLog`] assigns monotonically increasing versions to published
//! documents (version 0 = the initial document), keeps a bounded ring of
//! recent per-version patches, and answers sync requests with either
//! `up_to_date`, the missing patch chain, or a full document when the
//! requester has fallen behind the ring (gap → full resync). Every entry
//! carries the FxHash of the target version's canonical text so an
//! applier can detect divergence at the exact version it happened.

use std::collections::VecDeque;
use std::hash::Hasher;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::common::fxhash::FxHasher;
use crate::common::json::Json;

use super::codec::{field, ju64, pu64, pusize};

/// FxHash of a document's canonical compact text (the replication
/// layer's cheap divergence detector).
pub fn doc_hash(doc: &Json) -> u64 {
    let mut h = FxHasher::default();
    h.write(doc.to_compact().as_bytes());
    h.finish()
}

/// Equality with canonical-*text* semantics: numbers compare by bit
/// pattern, so `0.0` and `-0.0` — which the canonical writer prints
/// differently (`0` vs `-0`), and PR 4 deliberately made survive the
/// codec — are different values here. The derived `PartialEq` would call
/// them equal and make [`diff`] silently drop a sign-of-zero change,
/// breaking the byte-for-byte contract.
fn canonical_eq(a: &Json, b: &Json) -> bool {
    match (a, b) {
        (Json::Num(x), Json::Num(y)) => x.to_bits() == y.to_bits(),
        (Json::Arr(xs), Json::Arr(ys)) => {
            xs.len() == ys.len() && xs.iter().zip(ys).all(|(x, y)| canonical_eq(x, y))
        }
        (Json::Obj(xs), Json::Obj(ys)) => {
            xs.len() == ys.len()
                && xs
                    .iter()
                    .zip(ys)
                    .all(|((ka, va), (kb, vb))| ka == kb && canonical_eq(va, vb))
        }
        _ => a == b,
    }
}

fn op_set(path: &[Json], value: &Json) -> Json {
    let mut o = Json::obj();
    o.set("p", Json::Arr(path.to_vec())).set("v", value.clone());
    o
}

fn op_del(path: &[Json]) -> Json {
    let mut o = Json::obj();
    o.set("p", Json::Arr(path.to_vec())).set("d", true);
    o
}

fn op_truncate(path: &[Json], len: usize) -> Json {
    let mut o = Json::obj();
    o.set("p", Json::Arr(path.to_vec())).set("n", len);
    o
}

/// Structural diff: the op sequence that rewrites `old` into `new`
/// (empty when they are equal). Recurses into matching containers, and at
/// deeper levels keeps whichever encoding is smaller on the wire: the
/// child ops, or one op replacing the whole subtree. Both are exact —
/// the choice only affects delta bytes. (The collapse matters in
/// practice: checkpoint slot tables are code-*sorted arrays*, so one
/// inserted slot shifts a tail that would otherwise diff
/// element-by-element, and a dense cluster of tiny scalar edits can cost
/// more in repeated paths than the subtree it rewrites.)
pub fn diff(old: &Json, new: &Json) -> Json {
    let mut ops = Vec::new();
    let mut path = Vec::new();
    diff_into(old, new, &mut path, &mut ops);
    Json::Arr(ops)
}

/// Shallowest path depth at which [`diff_into`] considers replacing a
/// whole subtree. Above this (the document root, the `model` payload,
/// a tree's full node arena) a replacement is never a useful delta —
/// it approximates a full resync — and *measuring* it would serialize
/// nearly the whole document on every publish.
const COLLAPSE_MIN_DEPTH: usize = 3;

/// Record an op: its compact size is computed exactly once, here (+1 for
/// the separating comma in the patch array).
fn push_op(ops: &mut Vec<Json>, op: Json) -> usize {
    let bytes = op.to_compact().len() + 1;
    ops.push(op);
    bytes
}

/// Early-abort check that `value`'s compact serialization stays under
/// `cap` bytes. Approximate on escaped object keys — an overestimate can
/// only skip a borderline collapse, which costs a few delta bytes, never
/// exactness. The abort is what keeps [`diff`] from serializing a
/// near-document-sized subtree (a whole forest member, say) just to
/// discover the replacement loses to a handful of child ops.
fn fits_within(value: &Json, cap: usize) -> bool {
    fn take(remaining: &mut usize, n: usize) -> bool {
        if *remaining < n {
            false
        } else {
            *remaining -= n;
            true
        }
    }
    fn go(v: &Json, remaining: &mut usize) -> bool {
        match v {
            Json::Null => take(remaining, 4),
            Json::Bool(b) => take(remaining, if *b { 4 } else { 5 }),
            Json::Num(_) | Json::Str(_) => take(remaining, v.to_compact().len()),
            Json::Arr(items) => {
                take(remaining, 2 + items.len().saturating_sub(1))
                    && items.iter().all(|item| go(item, remaining))
            }
            Json::Obj(map) => {
                take(remaining, 2 + map.len().saturating_sub(1))
                    && map
                        .iter()
                        .all(|(k, item)| take(remaining, k.len() + 3) && go(item, remaining))
            }
        }
    }
    let mut remaining = cap;
    go(value, &mut remaining)
}

/// Append either `child_ops` (whose serialized size the caller
/// accumulated) or a single whole-subtree `set`, whichever is smaller.
/// Returns the appended bytes.
fn collapse_or_extend(
    new: &Json,
    path: &[Json],
    child_ops: Vec<Json>,
    child_bytes: usize,
    ops: &mut Vec<Json>,
) -> usize {
    // a replacement is at least the subtree itself, so only measure it
    // exactly when the subtree alone could undercut the child ops
    if path.len() >= COLLAPSE_MIN_DEPTH && fits_within(new, child_bytes) {
        let replace = op_set(path, new);
        let replace_bytes = replace.to_compact().len() + 1;
        if replace_bytes < child_bytes {
            ops.push(replace);
            return replace_bytes;
        }
    }
    ops.extend(child_ops);
    child_bytes
}

/// Returns the serialized size of the ops appended for this subtree.
fn diff_into(old: &Json, new: &Json, path: &mut Vec<Json>, ops: &mut Vec<Json>) -> usize {
    if canonical_eq(old, new) {
        return 0;
    }
    match (old, new) {
        (Json::Obj(a), Json::Obj(b)) => {
            let mut child_ops = Vec::new();
            let mut child_bytes = 0;
            for key in a.keys() {
                if !b.contains_key(key) {
                    path.push(Json::Str(key.clone()));
                    child_bytes += push_op(&mut child_ops, op_del(path));
                    path.pop();
                }
            }
            for (key, new_value) in b {
                path.push(Json::Str(key.clone()));
                child_bytes += match a.get(key) {
                    Some(old_value) => {
                        diff_into(old_value, new_value, path, &mut child_ops)
                    }
                    None => push_op(&mut child_ops, op_set(path, new_value)),
                };
                path.pop();
            }
            collapse_or_extend(new, path, child_ops, child_bytes, ops)
        }
        (Json::Arr(a), Json::Arr(b)) => {
            let mut child_ops = Vec::new();
            let mut child_bytes = 0;
            if b.len() < a.len() {
                child_bytes += push_op(&mut child_ops, op_truncate(path, b.len()));
            }
            let common = a.len().min(b.len());
            for i in 0..common {
                path.push(Json::Num(i as f64));
                child_bytes += diff_into(&a[i], &b[i], path, &mut child_ops);
                path.pop();
            }
            for (i, item) in b.iter().enumerate().skip(a.len()) {
                path.push(Json::Num(i as f64));
                child_bytes += push_op(&mut child_ops, op_set(path, item));
                path.pop();
            }
            collapse_or_extend(new, path, child_ops, child_bytes, ops)
        }
        _ => push_op(ops, op_set(path, new)),
    }
}

/// A path segment: object key or array index.
enum Seg<'a> {
    Key(&'a str),
    Index(usize),
}

fn seg(j: &Json) -> Result<Seg<'_>> {
    match j {
        Json::Str(s) => Ok(Seg::Key(s)),
        Json::Num(v) if *v >= 0.0 && *v == v.trunc() => Ok(Seg::Index(*v as usize)),
        other => Err(anyhow!("invalid path segment {other:?}")),
    }
}

/// Navigate to the value at `segs` (mutable).
fn locate<'a>(doc: &'a mut Json, segs: &[Json]) -> Result<&'a mut Json> {
    let mut cur = doc;
    for s in segs {
        cur = match (seg(s)?, cur) {
            (Seg::Key(k), Json::Obj(map)) => map
                .get_mut(k)
                .ok_or_else(|| anyhow!("patch path: missing key {k:?}"))?,
            (Seg::Index(i), Json::Arr(items)) => items
                .get_mut(i)
                .ok_or_else(|| anyhow!("patch path: index {i} out of range"))?,
            _ => return Err(anyhow!("patch path: segment does not match the document")),
        };
    }
    Ok(cur)
}

fn apply_op(doc: &mut Json, op: &Json) -> Result<()> {
    let path = op
        .get("p")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("patch op missing \"p\""))?;
    if let Some(n) = op.get("n") {
        let n = pusize(n, "n")?;
        match locate(doc, path)? {
            Json::Arr(items) => {
                if n > items.len() {
                    return Err(anyhow!("truncate to {n} beyond length {}", items.len()));
                }
                items.truncate(n);
                Ok(())
            }
            _ => Err(anyhow!("truncate target is not an array")),
        }
    } else if op.get("d").is_some() {
        let (last, parent_path) =
            path.split_last().ok_or_else(|| anyhow!("delete op with empty path"))?;
        match (seg(last)?, locate(doc, parent_path)?) {
            (Seg::Key(k), Json::Obj(map)) => {
                map.remove(k).ok_or_else(|| anyhow!("delete: missing key {k:?}"))?;
                Ok(())
            }
            _ => Err(anyhow!("delete target must be an object key")),
        }
    } else {
        let value = op.get("v").ok_or_else(|| anyhow!("patch op missing \"v\""))?;
        let Some((last, parent_path)) = path.split_last() else {
            *doc = value.clone(); // whole-document replacement
            return Ok(());
        };
        match (seg(last)?, locate(doc, parent_path)?) {
            (Seg::Key(k), Json::Obj(map)) => {
                map.insert(k.to_string(), value.clone());
                Ok(())
            }
            (Seg::Index(i), Json::Arr(items)) => {
                if i < items.len() {
                    items[i] = value.clone();
                } else if i == items.len() {
                    items.push(value.clone()); // append (diff emits in order)
                } else {
                    return Err(anyhow!("set index {i} beyond length {}", items.len()));
                }
                Ok(())
            }
            _ => Err(anyhow!("set target does not match the document")),
        }
    }
}

/// Apply a patch produced by [`diff`]: `apply(&a, &diff(&a, &b)) == b`.
pub fn apply(base: &Json, patch: &Json) -> Result<Json> {
    let ops = patch.as_arr().ok_or_else(|| anyhow!("patch must be an array of ops"))?;
    let mut doc = base.clone();
    for op in ops {
        apply_op(&mut doc, op)?;
    }
    Ok(doc)
}

/// One published version's delta record.
pub struct DeltaEntry {
    /// The version this patch upgrades *from* (target = `from + 1`).
    pub from: u64,
    /// The patch ops ([`diff`] output).
    pub ops: Json,
    /// Compact-text size of the patch.
    pub delta_bytes: usize,
    /// Compact-text size of the full document at the target version.
    pub full_bytes: usize,
    /// [`doc_hash`] of the document at the target version.
    pub hash: u64,
    /// When the target version was published (replication-lag metric).
    pub published: Instant,
    /// Wall-clock unix microseconds of the publication. Travels on
    /// `repl_sync` responses (`pub_us`) so followers can measure the
    /// live publish→apply freshness span; `Instant`s cannot cross
    /// processes. Assumes NTP-synced hosts — spans are clamped at zero
    /// on the follower under clock skew.
    pub published_unix_us: u64,
    /// Cumulative acked learns the target version covers (`learns` on
    /// the wire); 0 when the publisher did not supply it.
    pub learns_at_publish: u64,
}

/// Versioned delta publisher: owns the latest document, assigns versions,
/// and keeps a bounded ring of per-version patches for catch-up syncs.
/// The document lives behind an `Arc` so a full-sync response can leave
/// the serving lock after a pointer clone instead of a multi-MB deep
/// copy (see [`SyncPayload`]).
pub struct DeltaLog {
    version: u64,
    doc: Arc<Json>,
    hash: u64,
    full_bytes: usize,
    entries: VecDeque<DeltaEntry>,
    capacity: usize,
    /// Unix-µs publish instant of the head version (anchor instant for
    /// version 0). Shipped on full syncs so a bootstrapping follower
    /// records a freshness span too.
    published_unix_us: u64,
    /// Cumulative acked learns covered by the head version.
    learns_at_publish: u64,
}

impl DeltaLog {
    /// Start a log at version 0 with `doc` as the anchor. `capacity`
    /// bounds the delta ring — requesters further behind get a full
    /// document instead of a patch chain.
    pub fn new(doc: Json, capacity: usize) -> DeltaLog {
        let text = doc.to_compact();
        let mut h = FxHasher::default();
        h.write(text.as_bytes());
        DeltaLog {
            version: 0,
            hash: h.finish(),
            full_bytes: text.len(),
            doc: Arc::new(doc),
            entries: VecDeque::new(),
            capacity: capacity.max(1),
            published_unix_us: crate::obs::window::now_unix_us(),
            learns_at_publish: 0,
        }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn hash(&self) -> u64 {
        self.hash
    }

    /// The current full document.
    pub fn doc(&self) -> &Json {
        &self.doc
    }

    /// The current full document as a shared pointer (cheap to clone
    /// while holding a lock on the log).
    pub fn doc_arc(&self) -> Arc<Json> {
        self.doc.clone()
    }

    /// Compact-text size of the current full document.
    pub fn full_bytes(&self) -> usize {
        self.full_bytes
    }

    /// The retained delta ring, oldest first.
    pub fn entries(&self) -> impl Iterator<Item = &DeltaEntry> {
        self.entries.iter()
    }

    /// Publish a new document. Returns `(version, changed)`: an unchanged
    /// document does **not** bump the version (no-op deltas never enter
    /// the ring), so followers only ever see versions that differ.
    /// Stamped "now" with no learns marker — serving leaders publish
    /// through [`DeltaLog::publish_with`] instead.
    pub fn publish(&mut self, new_doc: Json) -> (u64, bool) {
        self.publish_with(new_doc, 0, crate::obs::window::now_unix_us())
    }

    /// [`DeltaLog::publish`] with an explicit publish instant (unix µs)
    /// and the cumulative acked learns the new document covers — the
    /// pair followers need to report live freshness and staleness.
    pub fn publish_with(&mut self, new_doc: Json, learns: u64, now_us: u64) -> (u64, bool) {
        if canonical_eq(&new_doc, &self.doc) {
            return (self.version, false);
        }
        let ops = diff(&self.doc, &new_doc);
        let text = new_doc.to_compact();
        let mut h = FxHasher::default();
        h.write(text.as_bytes());
        let hash = h.finish();
        self.entries.push_back(DeltaEntry {
            from: self.version,
            delta_bytes: ops.to_compact().len(),
            full_bytes: text.len(),
            hash,
            published: Instant::now(),
            published_unix_us: now_us,
            learns_at_publish: learns,
            ops,
        });
        while self.entries.len() > self.capacity {
            self.entries.pop_front();
        }
        self.version += 1;
        self.doc = Arc::new(new_doc);
        self.hash = hash;
        self.full_bytes = text.len();
        self.published_unix_us = now_us;
        self.learns_at_publish = learns;
        (self.version, true)
    }

    /// Decide what a requester at version `have` (`None` = knows
    /// nothing) should receive. Built while the caller holds its lock on
    /// the log, but cheap to build: delta ops are delta-sized clones and
    /// the full document travels as an `Arc` — the caller embeds it into
    /// the wire response *after* releasing the lock
    /// ([`SyncPayload::into_response`]), so a follower bootstrap never
    /// stalls the trainer's publish path on a multi-MB deep copy.
    pub fn sync_payload(&self, have: Option<u64>) -> SyncPayload {
        let (version, hash) = (self.version, self.hash);
        let (pub_us, learns) = (self.published_unix_us, self.learns_at_publish);
        let Some(have) = have else {
            return SyncPayload::Full { version, hash, pub_us, learns, doc: self.doc_arc() };
        };
        if have == self.version {
            return SyncPayload::UpToDate { version, hash };
        }
        let behind = self.version.wrapping_sub(have);
        if have < self.version && behind as usize <= self.entries.len() {
            let start = self.entries.len() - behind as usize;
            // the ring is contiguous by construction; verify anyway so a
            // logic bug degrades to a full sync instead of a bad chain
            if self.entries[start].from == have {
                let mut deltas = Json::Arr(Vec::new());
                for entry in self.entries.iter().skip(start) {
                    let mut d = Json::obj();
                    d.set("from", ju64(entry.from))
                        .set("to", ju64(entry.from + 1))
                        .set("hash", ju64(entry.hash))
                        .set("pub_us", ju64(entry.published_unix_us))
                        .set("learns", ju64(entry.learns_at_publish))
                        .set("ops", entry.ops.clone());
                    deltas.push(d);
                }
                return SyncPayload::Deltas { version, hash, deltas };
            }
        }
        // gap (requester behind the ring, ahead of us, or ring mismatch)
        SyncPayload::Full { version, hash, pub_us, learns, doc: self.doc_arc() }
    }
}

/// One sync decision ([`DeltaLog::sync_payload`]), embeddable into a
/// wire response outside the log lock.
pub enum SyncPayload {
    UpToDate { version: u64, hash: u64 },
    Deltas { version: u64, hash: u64, deltas: Json },
    Full { version: u64, hash: u64, pub_us: u64, learns: u64, doc: Arc<Json> },
}

impl SyncPayload {
    /// Write the `version`/`hash` header plus the variant's body into
    /// `response`. The full document is deep-cloned HERE — call this
    /// after releasing the log lock.
    pub fn into_response(self, response: &mut Json) {
        match self {
            SyncPayload::UpToDate { version, hash } => {
                response
                    .set("version", ju64(version))
                    .set("hash", ju64(hash))
                    .set("up_to_date", true);
            }
            SyncPayload::Deltas { version, hash, deltas } => {
                response
                    .set("version", ju64(version))
                    .set("hash", ju64(hash))
                    .set("deltas", deltas);
            }
            SyncPayload::Full { version, hash, pub_us, learns, doc } => {
                response
                    .set("version", ju64(version))
                    .set("hash", ju64(hash))
                    .set("pub_us", ju64(pub_us))
                    .set("learns", ju64(learns))
                    .set("full", (*doc).clone());
            }
        }
    }
}

/// Decode the `from`/`to`/`hash`/`ops` fields of one wire delta.
pub fn decode_wire_delta(d: &Json) -> Result<(u64, u64, u64, &Json)> {
    Ok((
        pu64(field(d, "from")?, "from")?,
        pu64(field(d, "to")?, "to")?,
        pu64(field(d, "hash")?, "hash")?,
        field(d, "ops")?,
    ))
}

/// The optional freshness stamps of one wire delta (or a `repl_sync`
/// response head): `(publish unix µs, learns covered)`. Both absent
/// when the leader predates the stamps — followers degrade gracefully.
pub fn wire_freshness(d: &Json) -> (Option<u64>, Option<u64>) {
    (
        d.get("pub_us").and_then(|j| pu64(j, "pub_us").ok()),
        d.get("learns").and_then(|j| pu64(j, "learns").ok()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    fn roundtrip(old: &str, new: &str) -> Json {
        let (a, b) = (parse(old), parse(new));
        let patch = diff(&a, &b);
        let applied = apply(&a, &patch).expect("apply");
        assert_eq!(applied.to_compact(), b.to_compact(), "patch {}", patch.to_compact());
        patch
    }

    #[test]
    fn diff_of_equal_docs_is_empty() {
        let a = parse(r#"{"x":[1,2,{"y":"z"}],"n":null}"#);
        assert_eq!(diff(&a, &a).to_compact(), "[]");
    }

    #[test]
    fn scalar_and_nested_changes() {
        roundtrip(r#"{"a":1,"b":{"c":2}}"#, r#"{"a":1,"b":{"c":3}}"#);
        roundtrip(r#"{"a":1}"#, r#"{"a":"now a string"}"#);
        roundtrip(r#"{"a":{"deep":{"er":[1,2]}}}"#, r#"{"a":{"deep":{"er":[1,5]}}}"#);
    }

    #[test]
    fn key_insertions_and_deletions() {
        roundtrip(r#"{"a":1,"b":2}"#, r#"{"a":1}"#);
        roundtrip(r#"{"a":1}"#, r#"{"a":1,"b":{"new":[1]}}"#);
        roundtrip(r#"{"a":1,"b":2,"c":3}"#, r#"{"d":4}"#);
    }

    #[test]
    fn array_grow_shrink_and_edit() {
        roundtrip("[1,2,3]", "[1,2,3,4,5]");
        roundtrip("[1,2,3,4,5]", "[1,2]");
        roundtrip("[1,2,3]", "[9,2,8]");
        roundtrip("[[1],[2]]", "[[1,1],[2]]");
        roundtrip("[1,2,3]", "[]");
        roundtrip("[]", "[1]");
        // shrink + edit + type change in one patch
        roundtrip(r#"[{"a":1},{"b":2},3]"#, r#"[{"a":9},"two"]"#);
    }

    #[test]
    fn type_mismatch_replaces_whole_subtree() {
        roundtrip(r#"{"a":[1,2]}"#, r#"{"a":{"k":1}}"#);
        roundtrip("[1]", r#"{"a":1}"#);
        roundtrip("1", "[1]");
    }

    #[test]
    fn diff_is_small_for_local_changes() {
        // a 200-element array with one edit: the patch must not ship the
        // other 199 elements
        let a = Json::Arr((0..200).map(|i| Json::Num(i as f64)).collect());
        let mut items: Vec<Json> = (0..200).map(|i| Json::Num(i as f64)).collect();
        items[117] = Json::Num(-1.0);
        let b = Json::Arr(items);
        let patch = diff(&a, &b);
        assert_eq!(patch.as_arr().unwrap().len(), 1);
        assert!(patch.to_compact().len() < 40, "{}", patch.to_compact());
    }

    #[test]
    fn apply_rejects_divergent_bases() {
        // bulky unchanged siblings keep the diff targeted at ["a","b"]
        // (a whole-subtree collapse would upsert instead of fail)
        let bulk = format!("\"bulk\":\"{}\"", "x".repeat(200));
        let a = parse(&format!(r#"{{"a":{{"b":1,{bulk}}},{bulk}}}"#));
        let b = parse(&format!(r#"{{"a":{{"b":2,{bulk}}},{bulk}}}"#));
        let patch = diff(&a, &b);
        assert_eq!(patch.as_arr().unwrap().len(), 1, "{}", patch.to_compact());
        // a base missing the path must fail loudly, not silently corrupt
        let unrelated = parse(r#"{"c":1}"#);
        assert!(apply(&unrelated, &patch).is_err());
        assert!(apply(&a, &parse(r#"[{"p":["a","x","y"],"v":1}]"#)).is_err());
        assert!(apply(&a, &parse(r#"[{"p":["a"],"n":"5"}]"#)).is_err());
        assert!(apply(&a, &parse(r#"{"not":"an array"}"#)).is_err());
    }

    #[test]
    fn dense_changes_collapse_to_one_subtree_op() {
        // every element of a small array (nested past COLLAPSE_MIN_DEPTH)
        // changes: one set of the whole array must beat per-element
        // path-heavy ops
        let wrap = |slots: &str| {
            parse(&format!(
                r#"{{"w":{{"d":{{"slots":{slots},"keep":"unchanged-sibling"}}}}}}"#
            ))
        };
        let a = wrap("[[1,1.0],[2,2.0],[3,3.0]]");
        let b = wrap("[[1,1.5],[2,2.5],[3,3.5]]");
        let patch = diff(&a, &b);
        let applied = apply(&a, &patch).unwrap();
        assert_eq!(applied.to_compact(), b.to_compact());
        assert_eq!(patch.as_arr().unwrap().len(), 1, "{}", patch.to_compact());
        // and the single op targets ["w","d","slots"], not the document
        let op = &patch.as_arr().unwrap()[0];
        assert_eq!(op.get("p").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
    }

    #[test]
    fn shallow_levels_never_collapse_to_whole_document_sets() {
        // even when a shallow rewrite would be byte-smaller, levels above
        // COLLAPSE_MIN_DEPTH stay as targeted ops: a whole-document (or
        // whole-model) set is a de-facto full resync, and measuring it
        // would serialize the entire document on every publish
        let a = parse(r#"{"a":{"b":1}}"#);
        let b = parse(r#"{"a":{"b":2}}"#);
        let patch = diff(&a, &b);
        assert_eq!(patch.to_compact(), r#"[{"p":["a","b"],"v":2}]"#);
        assert_eq!(apply(&a, &patch).unwrap().to_compact(), b.to_compact());
    }

    #[test]
    fn delta_log_versions_and_sync_paths() {
        let v0 = parse(r#"{"x":0}"#);
        let mut log = DeltaLog::new(v0.clone(), 2);
        assert_eq!(log.version(), 0);

        // unchanged publish: no version bump, no ring entry
        let (v, changed) = log.publish(v0.clone());
        assert_eq!((v, changed), (0, false));

        for i in 1..=4 {
            let (v, changed) = log.publish(parse(&format!(r#"{{"x":{i}}}"#)));
            assert_eq!((v, changed), (i, true));
        }
        assert_eq!(log.entries().count(), 2, "ring capacity respected");

        // up to date
        let mut r = Json::obj();
        log.sync_payload(Some(4)).into_response(&mut r);
        assert_eq!(r.get("up_to_date").and_then(Json::as_bool), Some(true));
        assert_eq!(pu64(r.get("version").unwrap(), "v").unwrap(), 4);

        // within the ring: delta chain that reconstructs the head
        let mut r = Json::obj();
        log.sync_payload(Some(2)).into_response(&mut r);
        let deltas = r.get("deltas").and_then(Json::as_arr).expect("delta chain");
        assert_eq!(deltas.len(), 2);
        let mut doc = parse(r#"{"x":2}"#);
        for d in deltas {
            let (from, to, hash, ops) = decode_wire_delta(d).unwrap();
            assert_eq!(to, from + 1);
            doc = apply(&doc, ops).unwrap();
            assert_eq!(doc_hash(&doc), hash, "hash mismatch at v{to}");
        }
        assert_eq!(doc.to_compact(), log.doc().to_compact());

        // behind the ring → full; unknown (None) → full; ahead → full
        for have in [Some(0), None, Some(99)] {
            let mut r = Json::obj();
            log.sync_payload(have).into_response(&mut r);
            assert!(r.get("full").is_some(), "have={have:?} must fall back to full");
            assert_eq!(
                r.get("full").unwrap().to_compact(),
                log.doc().to_compact()
            );
        }
    }

    #[test]
    fn sign_of_zero_changes_are_not_dropped() {
        // derived PartialEq calls 0.0 == -0.0; the canonical writer does
        // not ("0" vs "-0"), so the diff must ship the sign flip
        let a = parse(r#"{"w":0}"#);
        let mut b = Json::obj();
        b.set("w", Json::Num(-0.0));
        assert_eq!(b.to_compact(), r#"{"w":-0}"#);
        let patch = diff(&a, &b);
        assert_eq!(
            patch.as_arr().map(<[Json]>::len),
            Some(1),
            "sign-of-zero change must produce an op: {}",
            patch.to_compact()
        );
        assert_eq!(apply(&a, &patch).unwrap().to_compact(), b.to_compact());

        // and the log must treat it as a real new version
        let mut log = DeltaLog::new(a, 4);
        let (version, changed) = log.publish(b);
        assert!(changed, "sign flip must bump the version");
        assert_eq!(version, 1);
    }

    #[test]
    fn log_hash_matches_doc_hash() {
        let mut log = DeltaLog::new(parse(r#"{"a":1}"#), 8);
        log.publish(parse(r#"{"a":2,"b":[1,2,3]}"#));
        assert_eq!(log.hash(), doc_hash(log.doc()));
        assert_eq!(log.full_bytes(), log.doc().to_compact().len());
    }

    #[test]
    fn freshness_stamps_travel_on_both_sync_shapes() {
        let mut log = DeltaLog::new(parse(r#"{"x":0}"#), 8);
        log.publish_with(parse(r#"{"x":1}"#), 500, 1_000_000);
        log.publish_with(parse(r#"{"x":2}"#), 900, 2_500_000);

        // delta chain: each wire delta carries its own version's stamps
        let mut r = Json::obj();
        log.sync_payload(Some(0)).into_response(&mut r);
        let deltas = r.get("deltas").and_then(Json::as_arr).unwrap();
        assert_eq!(deltas.len(), 2);
        assert_eq!(wire_freshness(&deltas[0]), (Some(1_000_000), Some(500)));
        assert_eq!(wire_freshness(&deltas[1]), (Some(2_500_000), Some(900)));

        // full sync: the head's stamps ride the response itself
        let mut r = Json::obj();
        log.sync_payload(None).into_response(&mut r);
        assert!(r.get("full").is_some());
        assert_eq!(wire_freshness(&r), (Some(2_500_000), Some(900)));

        // a stamp-less payload (old leader) degrades to None, not error
        assert_eq!(wire_freshness(&parse(r#"{"from":"1"}"#)), (None, None));

        // plain publish stamps wall-clock time and no learns marker
        log.publish(parse(r#"{"x":3}"#));
        let mut r = Json::obj();
        log.sync_payload(None).into_response(&mut r);
        let (pub_us, learns) = wire_freshness(&r);
        assert!(pub_us.unwrap() > 2_500_000, "wall-clock stamp expected");
        assert_eq!(learns, Some(0));
    }
}
