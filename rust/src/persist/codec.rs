//! Exact-value JSON encoding primitives shared by every model codec.
//!
//! The checkpoint contract is *bit-for-bit* restoration, so the helpers
//! here are strict about the two places plain JSON numbers would lose
//! information:
//!
//! * **`u64`/`usize`/`i64`** — an `f64` has 53 mantissa bits, so values
//!   like RNG words or `usize::MAX` depth caps cannot travel as JSON
//!   numbers. [`ju64`]/[`ji64`] encode them as decimal strings;
//!   [`pu64`]/[`pi64`] parse them back exactly.
//! * **non-finite `f64`** — JSON has no NaN/±∞ and the writer turns them
//!   into `null`. [`jf64`] encodes them as the tagged strings `"NaN"`,
//!   `"inf"`, `"-inf"` instead; finite values stay plain numbers (whose
//!   shortest-round-trip Display representation is exact, see
//!   [`crate::common::json`]).
//!
//! Decode helpers all return `anyhow::Result` with the offending key in
//! the message, so a corrupt checkpoint fails loudly at load time rather
//! than as a silently different model.

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::common::Rng;
use crate::stats::VarStats;

/// Encode an `f64` exactly (non-finite values become tagged strings).
pub fn jf64(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("NaN".to_string())
    } else if v > 0.0 {
        Json::Str("inf".to_string())
    } else {
        Json::Str("-inf".to_string())
    }
}

/// Decode an `f64` written by [`jf64`].
pub fn pf64(j: &Json, key: &str) -> Result<f64> {
    match j {
        Json::Num(v) => Ok(*v),
        Json::Str(s) => match s.as_str() {
            "NaN" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            other => Err(anyhow!("field {key:?}: not a number: {other:?}")),
        },
        other => Err(anyhow!("field {key:?}: expected a number, got {other:?}")),
    }
}

/// Encode a `u64` exactly (decimal string — f64 would round above 2^53).
pub fn ju64(v: u64) -> Json {
    Json::Str(v.to_string())
}

/// Decode a `u64` written by [`ju64`].
pub fn pu64(j: &Json, key: &str) -> Result<u64> {
    match j {
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| anyhow!("field {key:?}: not a u64: {s:?}")),
        // tolerate plain numbers for small values (hand-edited checkpoints)
        Json::Num(v) if *v >= 0.0 && *v == v.trunc() && *v <= 2f64.powi(53) => {
            Ok(*v as u64)
        }
        other => Err(anyhow!("field {key:?}: expected a u64, got {other:?}")),
    }
}

/// Encode an `i64` exactly (decimal string, like [`ju64`]).
pub fn ji64(v: i64) -> Json {
    Json::Str(v.to_string())
}

/// Decode an `i64` written by [`ji64`].
pub fn pi64(j: &Json, key: &str) -> Result<i64> {
    match j {
        Json::Str(s) => s
            .parse::<i64>()
            .map_err(|_| anyhow!("field {key:?}: not an i64: {s:?}")),
        Json::Num(v) if *v == v.trunc() && v.abs() <= 2f64.powi(53) => Ok(*v as i64),
        other => Err(anyhow!("field {key:?}: expected an i64, got {other:?}")),
    }
}

/// Encode a `usize` exactly.
pub fn jusize(v: usize) -> Json {
    ju64(v as u64)
}

/// Decode a `usize` written by [`jusize`].
pub fn pusize(j: &Json, key: &str) -> Result<usize> {
    let v = pu64(j, key)?;
    usize::try_from(v).map_err(|_| anyhow!("field {key:?}: {v} overflows usize"))
}

/// Decode a `bool`.
pub fn pbool(j: &Json, key: &str) -> Result<bool> {
    j.as_bool()
        .ok_or_else(|| anyhow!("field {key:?}: expected a bool"))
}

/// Decode a string slice.
pub fn pstr<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.as_str()
        .ok_or_else(|| anyhow!("field {key:?}: expected a string"))
}

/// Decode an array slice.
pub fn parr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
    j.as_arr()
        .ok_or_else(|| anyhow!("field {key:?}: expected an array"))
}

/// Object field lookup that errors with the key name.
pub fn field<'a>(obj: &'a Json, key: &str) -> Result<&'a Json> {
    obj.get(key)
        .ok_or_else(|| anyhow!("missing field {key:?}"))
}

/// [`VarStats`] as the compact triple `[n, mean, m2]`.
pub fn varstats_to_json(s: &VarStats) -> Json {
    Json::Arr(vec![jf64(s.n), jf64(s.mean), jf64(s.m2)])
}

/// Decode a [`VarStats`] triple written by [`varstats_to_json`].
pub fn varstats_from(j: &Json, key: &str) -> Result<VarStats> {
    let items = parr(j, key)?;
    if items.len() != 3 {
        return Err(anyhow!("field {key:?}: expected [n, mean, m2]"));
    }
    Ok(VarStats {
        n: pf64(&items[0], key)?,
        mean: pf64(&items[1], key)?,
        m2: pf64(&items[2], key)?,
    })
}

/// The PRNG's full state: xoshiro words plus the cached Box–Muller spare.
pub fn rng_to_json(rng: &Rng) -> Json {
    let (s, spare) = rng.state();
    let mut o = Json::obj();
    o.set("s", Json::Arr(s.iter().map(|&w| ju64(w)).collect()));
    o.set("spare", spare.map(jf64).unwrap_or(Json::Null));
    o
}

/// Decode a PRNG written by [`rng_to_json`].
pub fn rng_from(j: &Json, key: &str) -> Result<Rng> {
    let words = parr(field(j, "s")?, key)?;
    if words.len() != 4 {
        return Err(anyhow!("field {key:?}: expected 4 rng words"));
    }
    let mut s = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        s[i] = pu64(w, key)?;
    }
    let spare = field(j, "spare")?;
    let spare = if spare.is_null() { None } else { Some(pf64(spare, key)?) };
    Ok(Rng::from_state(s, spare))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip_is_exact_above_2_53() {
        for v in [0u64, 1, u64::MAX, u64::MAX - 1, (1u64 << 53) + 1] {
            let j = ju64(v);
            let text = j.to_compact();
            let back = Json::parse(&text).unwrap();
            assert_eq!(pu64(&back, "t").unwrap(), v);
        }
    }

    #[test]
    fn i64_roundtrip() {
        for v in [i64::MIN, -1, 0, 1, i64::MAX] {
            let back = Json::parse(&ji64(v).to_compact()).unwrap();
            assert_eq!(pi64(&back, "t").unwrap(), v);
        }
    }

    #[test]
    fn f64_roundtrip_covers_special_values() {
        for v in [0.0, -0.0, 0.1 + 0.2, f64::MIN_POSITIVE, 1e308, -1e-308] {
            let back = Json::parse(&jf64(v).to_compact()).unwrap();
            assert_eq!(pf64(&back, "t").unwrap().to_bits(), v.to_bits());
        }
        let nan = Json::parse(&jf64(f64::NAN).to_compact()).unwrap();
        assert!(pf64(&nan, "t").unwrap().is_nan());
        let inf = Json::parse(&jf64(f64::INFINITY).to_compact()).unwrap();
        assert_eq!(pf64(&inf, "t").unwrap(), f64::INFINITY);
        let ninf = Json::parse(&jf64(f64::NEG_INFINITY).to_compact()).unwrap();
        assert_eq!(pf64(&ninf, "t").unwrap(), f64::NEG_INFINITY);
    }

    #[test]
    fn varstats_roundtrip() {
        let mut s = VarStats::new();
        s.update(1.5, 1.0);
        s.update(-2.5, 2.0);
        let back =
            varstats_from(&Json::parse(&varstats_to_json(&s).to_compact()).unwrap(), "t")
                .unwrap();
        assert_eq!(back.n.to_bits(), s.n.to_bits());
        assert_eq!(back.mean.to_bits(), s.mean.to_bits());
        assert_eq!(back.m2.to_bits(), s.m2.to_bits());
    }

    #[test]
    fn rng_roundtrip_continues_identically() {
        let mut rng = Rng::new(5);
        rng.normal(0.0, 1.0); // populate the spare
        let j = Json::parse(&rng_to_json(&rng).to_compact()).unwrap();
        let mut back = rng_from(&j, "rng").unwrap();
        for _ in 0..8 {
            assert_eq!(rng.next_u64(), back.next_u64());
            assert_eq!(rng.normal(0.0, 1.0).to_bits(), back.normal(0.0, 1.0).to_bits());
        }
    }

    #[test]
    fn decode_errors_name_the_field() {
        let j = Json::parse("{\"a\": true}").unwrap();
        let err = format!("{}", field(&j, "missing").unwrap_err());
        assert!(err.contains("missing"));
        let err = format!("{}", pf64(field(&j, "a").unwrap(), "a").unwrap_err());
        assert!(err.contains("\"a\""));
    }
}
