//! Compact length-prefixed **binary** checkpoint codec — the disk + wire
//! fast path beside the canonical JSON text (`docs/FORMATS.md`).
//!
//! JSON stays the canonical, debuggable interchange: a binary checkpoint
//! is nothing but an alternate serialization of the *same* canonical
//! document ([`super::Model::to_checkpoint`]), so decoding it and
//! re-encoding as JSON reproduces the canonical text byte-for-byte. The
//! envelope carries the [`doc_hash`] of that canonical text, which is
//! what lets delta chains and follower hash-verification stay valid
//! across formats.
//!
//! ## Envelope layout (all integers little-endian)
//!
//! ```text
//! offset size field
//!      0    4 magic "QOSB"
//!      4    2 format version (currently 1)
//!      6    2 flags (reserved, must be 0)
//!      8    8 doc_hash — FxHash64 of the canonical compact JSON text
//!     16    8 payload length N
//!     24    N payload: one binary-encoded value (below)
//! 24 + N    4 trailer magic "QOSE"
//! 28 + N    8 payload_hash — FxHash64 of the payload bytes
//! ```
//!
//! ## Value encoding
//!
//! One tag byte, then tag-specific data; lengths/counts are LEB128
//! varints. Numbers follow the same exactness rules as the JSON codec
//! ([`super::codec`]): every `f64` travels by bit pattern — integral
//! values (whose bits survive an i64 round-trip, which excludes `-0.0`
//! and the non-finites) as a zigzag varint, everything else as the raw
//! 8-byte IEEE-754 image.
//!
//! ```text
//! 0x00 null        0x01 false       0x02 true
//! 0x03 f64 — 8 bytes of to_bits()
//! 0x04 integral f64 — zigzag LEB128 of the value as i64
//! 0x05 string — varint byte length + UTF-8 bytes
//! 0x06 array — varint count + that many values
//! 0x07 object — varint count + (varint key length + key bytes + value)…
//!      in ascending key order (the canonical JSON writer's order)
//! ```
//!
//! Decoding is strict: unknown tags, truncated lengths, non-UTF-8 keys,
//! unsorted/duplicate object keys, trailing payload bytes and depth
//! beyond [`MAX_DEPTH`] are all hard errors, mirroring the JSON
//! parser's posture. [`crate::audit::invariants::verify_binary`]
//! re-checks the envelope/trailer independently (rules `BIN_ENVELOPE`
//! and `BIN_TRAILER`).

use std::hash::Hasher;

use anyhow::{anyhow, Result};

use crate::common::fxhash::FxHasher;
use crate::common::json::Json;

use super::delta::doc_hash;

/// Envelope magic: "qostream binary" header.
pub const MAGIC: &[u8; 4] = b"QOSB";
/// Trailer magic ("end" marker guarding against truncation).
pub const TRAILER_MAGIC: &[u8; 4] = b"QOSE";
/// Binary format version (independent of the checkpoint *document*
/// version, which travels inside the payload like any other field).
pub const BIN_VERSION: u16 = 1;
/// Envelope header size in bytes.
pub const HEADER_LEN: usize = 24;
/// Trailer size in bytes.
pub const TRAILER_LEN: usize = 12;
/// Maximum nesting depth accepted by the decoder (matches the JSON
/// parser's recursion cap).
pub const MAX_DEPTH: usize = 64;

/// Does this byte string look like a binary checkpoint? (Magic sniff —
/// lets [`super::Model::load`] accept either format from one path.)
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC
}

fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h = FxHasher::default();
    h.write(bytes);
    h.finish()
}

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

#[inline]
fn zigzag(i: i64) -> u64 {
    // shift in u64 space: `i64 << 1` would overflow-panic in debug builds
    ((i as u64) << 1) ^ ((i >> 63) as u64)
}

#[inline]
fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Append the binary encoding of one value (no envelope) to `out`.
pub fn encode_value(j: &Json, out: &mut Vec<u8>) {
    match j {
        Json::Null => out.push(0x00),
        Json::Bool(false) => out.push(0x01),
        Json::Bool(true) => out.push(0x02),
        Json::Num(v) => {
            // integral fast path: exact iff the bit pattern survives the
            // i64 round-trip (rejects -0.0, NaN, infinities, huge values)
            let i = *v as i64;
            if (i as f64).to_bits() == v.to_bits() {
                out.push(0x04);
                push_varint(out, zigzag(i));
            } else {
                out.push(0x03);
                out.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
        Json::Str(s) => {
            out.push(0x05);
            push_varint(out, s.len() as u64);
            out.extend_from_slice(s.as_bytes());
        }
        Json::Arr(items) => {
            out.push(0x06);
            push_varint(out, items.len() as u64);
            for item in items {
                encode_value(item, out);
            }
        }
        Json::Obj(map) => {
            out.push(0x07);
            push_varint(out, map.len() as u64);
            // BTreeMap iterates in ascending key order — the same order
            // the canonical JSON writer emits
            for (k, v) in map {
                push_varint(out, k.len() as u64);
                out.extend_from_slice(k.as_bytes());
                encode_value(v, out);
            }
        }
    }
}

/// Encode a value (no envelope) into a fresh buffer.
pub fn encode_value_vec(j: &Json) -> Vec<u8> {
    let mut out = Vec::new();
    encode_value(j, &mut out);
    out
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| anyhow!("binary value truncated at byte {}", self.pos))?;
        let slice = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(slice)
    }

    fn byte(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = self.byte()?;
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                // canonical LEB128: no gratuitous trailing zero-groups
                if byte == 0 && shift != 0 {
                    return Err(anyhow!("binary varint has a redundant final byte"));
                }
                return Ok(v);
            }
        }
        Err(anyhow!("binary varint longer than 64 bits"))
    }

    fn len(&mut self) -> Result<usize> {
        let v = self.varint()?;
        usize::try_from(v).map_err(|_| anyhow!("binary length {v} overflows usize"))
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return Err(anyhow!("binary value nested deeper than {MAX_DEPTH}"));
        }
        match self.byte()? {
            0x00 => Ok(Json::Null),
            0x01 => Ok(Json::Bool(false)),
            0x02 => Ok(Json::Bool(true)),
            0x03 => {
                let raw: [u8; 8] = self.take(8)?.try_into().expect("len 8");
                Ok(Json::Num(f64::from_bits(u64::from_le_bytes(raw))))
            }
            0x04 => Ok(Json::Num(unzigzag(self.varint()?) as f64)),
            0x05 => {
                let n = self.len()?;
                let s = std::str::from_utf8(self.take(n)?)
                    .map_err(|_| anyhow!("binary string is not UTF-8"))?;
                Ok(Json::Str(s.to_string()))
            }
            0x06 => {
                let n = self.len()?;
                let mut items = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    items.push(self.value(depth + 1)?);
                }
                Ok(Json::Arr(items))
            }
            0x07 => {
                let n = self.len()?;
                let mut map = std::collections::BTreeMap::new();
                let mut last: Option<String> = None;
                for _ in 0..n {
                    let klen = self.len()?;
                    let key = std::str::from_utf8(self.take(klen)?)
                        .map_err(|_| anyhow!("binary object key is not UTF-8"))?
                        .to_string();
                    if last.as_deref() >= Some(key.as_str()) {
                        return Err(anyhow!(
                            "binary object keys out of order (…{key:?})"
                        ));
                    }
                    let value = self.value(depth + 1)?;
                    last = Some(key.clone());
                    map.insert(key, value);
                }
                Ok(Json::Obj(map))
            }
            tag => Err(anyhow!("unknown binary value tag {tag:#04x}")),
        }
    }
}

/// Decode one binary-encoded value; the input must be exactly one value
/// with no trailing bytes.
pub fn decode_value(bytes: &[u8]) -> Result<Json> {
    let mut r = Reader { bytes, pos: 0 };
    let v = r.value(0)?;
    if r.pos != bytes.len() {
        return Err(anyhow!(
            "binary value has {} trailing bytes",
            bytes.len() - r.pos
        ));
    }
    Ok(v)
}

/// Wrap a canonical checkpoint document in the full binary envelope
/// (header + payload + trailer). The header's `doc_hash` is computed
/// from the document's canonical JSON text, so it equals the hash the
/// delta log and the replication protocol already use.
pub fn encode_doc(doc: &Json) -> Vec<u8> {
    let payload = encode_value_vec(doc);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&BIN_VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes()); // flags
    out.extend_from_slice(&doc_hash(doc).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(TRAILER_MAGIC);
    out.extend_from_slice(&hash_bytes(&payload).to_le_bytes());
    out
}

/// Parsed envelope header fields (exposed for the audit layer, which
/// re-verifies them with findings instead of errors).
pub struct Envelope<'a> {
    pub version: u16,
    pub flags: u16,
    pub doc_hash: u64,
    pub payload: &'a [u8],
    pub trailer_hash: u64,
}

/// Split a binary checkpoint into its envelope parts, verifying magic,
/// version, length accounting and the trailer's payload hash.
pub fn read_envelope(bytes: &[u8]) -> Result<Envelope<'_>> {
    if bytes.len() < HEADER_LEN + TRAILER_LEN {
        return Err(anyhow!(
            "binary checkpoint too short ({} bytes; envelope needs {})",
            bytes.len(),
            HEADER_LEN + TRAILER_LEN
        ));
    }
    if &bytes[0..4] != MAGIC {
        return Err(anyhow!("binary checkpoint has a bad magic header"));
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().expect("len 2"));
    if version != BIN_VERSION {
        return Err(anyhow!(
            "binary checkpoint version {version} unsupported (this build reads {BIN_VERSION})"
        ));
    }
    let flags = u16::from_le_bytes(bytes[6..8].try_into().expect("len 2"));
    if flags != 0 {
        return Err(anyhow!("binary checkpoint has unknown flags {flags:#06x}"));
    }
    let doc_hash = u64::from_le_bytes(bytes[8..16].try_into().expect("len 8"));
    let payload_len = u64::from_le_bytes(bytes[16..24].try_into().expect("len 8"));
    let expected = (bytes.len() - HEADER_LEN - TRAILER_LEN) as u64;
    if payload_len != expected {
        return Err(anyhow!(
            "binary checkpoint length mismatch: header claims {payload_len} payload bytes, file has {expected}"
        ));
    }
    let payload = &bytes[HEADER_LEN..bytes.len() - TRAILER_LEN];
    let trailer = &bytes[bytes.len() - TRAILER_LEN..];
    if &trailer[0..4] != TRAILER_MAGIC {
        return Err(anyhow!("binary checkpoint has a bad trailer magic"));
    }
    let trailer_hash = u64::from_le_bytes(trailer[4..12].try_into().expect("len 8"));
    let actual = hash_bytes(payload);
    if trailer_hash != actual {
        return Err(anyhow!(
            "binary checkpoint payload hash mismatch (trailer {trailer_hash:#018x}, computed {actual:#018x})"
        ));
    }
    Ok(Envelope { version, flags, doc_hash, payload, trailer_hash })
}

/// Decode a full binary checkpoint back into its canonical document,
/// verifying the envelope, the trailer hash, and that the decoded
/// document's canonical text matches the header's `doc_hash` — the
/// cross-format equivalence guarantee.
pub fn decode_doc(bytes: &[u8]) -> Result<Json> {
    let env = read_envelope(bytes)?;
    let doc = decode_value(env.payload)?;
    let canonical = doc_hash(&doc);
    if canonical != env.doc_hash {
        return Err(anyhow!(
            "binary checkpoint doc_hash mismatch: header {:#018x}, canonical JSON {:#018x}",
            env.doc_hash,
            canonical
        ));
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Json {
        Json::parse(text).unwrap()
    }

    #[test]
    fn value_roundtrip_covers_every_shape() {
        let doc = parse(
            r#"{"arr":[1,2.5,-3,"s",null,true,false],"nested":{"a":{"b":[{"c":0}]}},"big":"18446744073709551615"}"#,
        );
        let bytes = encode_value_vec(&doc);
        let back = decode_value(&bytes).unwrap();
        assert_eq!(back.to_compact(), doc.to_compact());
    }

    #[test]
    fn floats_roundtrip_by_bit_pattern() {
        for v in [
            0.0,
            -0.0,
            1.5,
            -1.0 / 3.0,
            f64::MIN_POSITIVE,
            f64::MAX,
            1e300,
            -2.2250738585072014e-308,
            9007199254740993.0, // 2^53 + 1: not exactly representable as written
        ] {
            let j = Json::Num(v);
            let back = decode_value(&encode_value_vec(&j)).unwrap();
            let Json::Num(b) = back else { panic!("not a number") };
            assert_eq!(b.to_bits(), v.to_bits(), "value {v}");
        }
        // -0.0 must NOT take the integral path (it would decode as +0.0)
        let bytes = encode_value_vec(&Json::Num(-0.0));
        assert_eq!(bytes[0], 0x03, "-0.0 must use the raw f64 tag");
        let bytes = encode_value_vec(&Json::Num(7.0));
        assert_eq!(bytes[0], 0x04, "integral values use the varint tag");
        assert_eq!(bytes.len(), 2, "small ints are two bytes");
    }

    #[test]
    fn envelope_roundtrips_and_hashes_match() {
        let doc = parse(r#"{"format":"qostream-checkpoint","model":{"w":[0.25,1,2]},"version":"1"}"#);
        let bytes = encode_doc(&doc);
        assert!(is_binary(&bytes));
        let env = read_envelope(&bytes).unwrap();
        assert_eq!(env.version, BIN_VERSION);
        assert_eq!(env.doc_hash, doc_hash(&doc));
        let back = decode_doc(&bytes).unwrap();
        assert_eq!(back.to_compact(), doc.to_compact());
    }

    #[test]
    fn corruption_anywhere_is_detected() {
        let doc = parse(r#"{"k":[1,2,3.5,"x"],"m":{"n":null}}"#);
        let bytes = encode_doc(&doc);
        // header magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(read_envelope(&bad).is_err());
        // version
        let mut bad = bytes.clone();
        bad[4] = 0x7f;
        assert!(read_envelope(&bad).is_err());
        // payload byte → trailer hash mismatch
        let mut bad = bytes.clone();
        bad[HEADER_LEN + 2] ^= 0x01;
        assert!(read_envelope(&bad).is_err());
        // trailer magic
        let mut bad = bytes.clone();
        let t = bad.len() - TRAILER_LEN;
        bad[t] ^= 0xff;
        assert!(read_envelope(&bad).is_err());
        // truncation
        assert!(read_envelope(&bytes[..bytes.len() - 1]).is_err());
        assert!(read_envelope(&bytes[..HEADER_LEN - 1]).is_err());
    }

    #[test]
    fn doc_hash_mismatch_is_detected() {
        let doc = parse(r#"{"a":1}"#);
        let mut bytes = encode_doc(&doc);
        // flip a doc_hash byte; payload + trailer stay consistent
        bytes[9] ^= 0x01;
        let err = decode_doc(&bytes).unwrap_err().to_string();
        assert!(err.contains("doc_hash"), "{err}");
    }

    #[test]
    fn strict_decoding_rejects_malformed_values() {
        assert!(decode_value(&[0x08]).is_err(), "unknown tag");
        assert!(decode_value(&[0x03, 1, 2]).is_err(), "truncated f64");
        assert!(decode_value(&[0x05, 0x02, b'a']).is_err(), "truncated string");
        assert!(decode_value(&[0x05, 0x01, 0xff]).is_err(), "invalid UTF-8");
        assert!(decode_value(&[0x00, 0x00]).is_err(), "trailing bytes");
        // unsorted keys: {"b":null,"a":null} in wire order b, a
        let mut bad = vec![0x07, 0x02];
        bad.extend_from_slice(&[0x01, b'b', 0x00, 0x01, b'a', 0x00]);
        assert!(decode_value(&bad).is_err(), "unsorted object keys");
        // deep nesting beyond the cap: [[[…null…]]]
        let mut deep = Vec::new();
        for _ in 0..MAX_DEPTH + 2 {
            deep.extend_from_slice(&[0x06, 0x01]);
        }
        deep.push(0x00);
        assert!(decode_value(&deep).is_err(), "depth cap");
    }
}
