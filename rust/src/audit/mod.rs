//! `audit/` — static analysis for the model state and the repo itself.
//!
//! Two halves, one findings vocabulary:
//!
//! * [`invariants`] — a **model-invariant verifier** that walks a
//!   canonical checkpoint document (or a live [`crate::persist::Model`])
//!   and checks the full catalog in `docs/INVARIANTS.md`: arena topology
//!   (children after parents, no orphans, depth under cap), QO slot
//!   tables (strictly code-sorted, positive weights, finite mergeable
//!   [`crate::stats::VarStats`] — paper Sec. 3), E-BST ordering, leaf
//!   linear-model finiteness, deferred-attempt queue liveness, delta
//!   hash-chain continuity, and `mem_bytes()` self-consistency.
//! * [`lint`] — a std-only **source scanner** enforcing repo rules over
//!   `rust/src/`: no `unwrap()`/`expect()` on serve/replicate connection
//!   paths, no allocation or locking in the `obs` hot path outside an
//!   allow-list, checkpointability of every [`crate::observer::ObserverSpec`]
//!   kind, `#![forbid(unsafe_code)]` in every crate root, and module
//!   docs on every public module.
//!
//! Both emit structured [`Finding`]s (rule id + location + expected vs
//! actual) rather than a bare bool, so a corrupted checkpoint or a rule
//! violation is *explainable* — the serve layer quotes the failing rule
//! in a follower's `last_resync_cause`, and CI prints findings as NDJSON.
//!
//! Verification is **zero-cost on release hot paths**: the boundary
//! hooks (persist load, follower delta-apply, leader publish) only run
//! under `debug_assertions` or behind the explicit `qostream audit` CLI
//! subcommand; the rejection paths in [`crate::serve::replicate`] run it
//! only after an apply already failed.

pub mod invariants;
pub mod lint;

use crate::common::json::Json;

/// One structured static-analysis finding.
///
/// `rule` is a stable identifier from `docs/INVARIANTS.md` (invariant
/// rules) or the lint catalog in [`lint`]; `path` locates the violation
/// (a dotted document path like `model.nodes[3].split.left`, or a
/// `file:line` pair with `line` set for lint findings); `message` states
/// expected vs actual.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    /// Stable rule identifier (e.g. `ARENA_CHILD_ORDER`).
    pub rule: &'static str,
    /// Document path or source file locating the violation.
    pub path: String,
    /// Source line (lint findings only).
    pub line: Option<usize>,
    /// Human-readable expected-vs-actual statement.
    pub message: String,
}

impl Finding {
    pub fn new(rule: &'static str, path: impl Into<String>, message: impl Into<String>) -> Finding {
        Finding { rule, path: path.into(), line: None, message: message.into() }
    }

    pub fn at_line(
        rule: &'static str,
        path: impl Into<String>,
        line: usize,
        message: impl Into<String>,
    ) -> Finding {
        Finding { rule, path: path.into(), line: Some(line), message: message.into() }
    }

    /// Machine-readable encoding (one NDJSON line per finding in the CLI).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("rule", self.rule).set("path", self.path.as_str());
        if let Some(line) = self.line {
            o.set("line", line);
        }
        o.set("message", self.message.as_str());
        o
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.line {
            Some(line) => write!(f, "{} {}:{} {}", self.rule, self.path, line, self.message),
            None => write!(f, "{} at {}: {}", self.rule, self.path, self.message),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finding_renders_rule_path_and_message() {
        let f = Finding::new("ARENA_CHILD_ORDER", "model.nodes[3]", "left 2 <= parent 3");
        assert_eq!(format!("{f}"), "ARENA_CHILD_ORDER at model.nodes[3]: left 2 <= parent 3");
        let j = f.to_json().to_compact();
        assert!(j.contains("\"rule\":\"ARENA_CHILD_ORDER\""), "{j}");
        assert!(j.contains("\"path\":\"model.nodes[3]\""), "{j}");

        let l = Finding::at_line("LINT_UNWRAP_CONN", "rust/src/serve/server.rs", 42, "unwrap()");
        assert_eq!(format!("{l}"), "LINT_UNWRAP_CONN rust/src/serve/server.rs:42 unwrap()");
        assert!(l.to_json().to_compact().contains("\"line\":42"));
    }
}
