//! Repo lint pass: a std-only source scanner enforcing qostream's
//! repo-specific rules over `rust/src/` (plus the crate roots).
//!
//! These are rules `rustc`/clippy cannot know about — they encode *this*
//! system's contracts:
//!
//! * [`LINT_UNWRAP_CONN`] — no `.unwrap()`/`.expect(` on the
//!   serve/replicate connection-handling paths. A panic there kills a
//!   connection (or poll) thread, which a malformed peer must never be
//!   able to do; errors must flow back as protocol error responses.
//! * [`LINT_OBS_HOT_PATH`] — no allocation or locking in
//!   `obs/mod.rs` outside the allow-listed cold-path functions. The
//!   instrumentation contract (PR 6's ≤5% `obs_overhead_ratio` gate)
//!   rests on every recording site being relaxed atomics only.
//! * [`LINT_GOVERN_HOT_PATH`] — no allocation or locking in the
//!   per-learn budget check of `govern/mod.rs` (`Governor::over_budget`
//!   and its Copy accessors). The governance contract (docs/MEMORY.md)
//!   is that deciding *whether* to govern costs one integer compare;
//!   only the triggered escalation (`enforce`) may allocate.
//! * [`LINT_OBSERVER_SPEC`] — every observer kind registered with
//!   [`crate::observer::ObserverSpec`] implements `mem_bytes` +
//!   `to_json` in its `AttributeObserver` impl and `from_json` in its
//!   file, so persist and memory accounting cover every kind.
//! * [`LINT_FORBID_UNSAFE`] — `#![forbid(unsafe_code)]` in every crate
//!   root (qostream lib/bin, both vendor shims, the lint tool itself).
//! * [`LINT_MODULE_DOCS`] — every public module reachable from `lib.rs`
//!   opens with `//!` module docs.
//!
//! A line carrying an `audit:allow(<rule>)` comment is exempt — the
//! comment doubles as the in-source justification the CI gate requires.
//! The scanner is deliberately line-based and rustfmt-shaped (this repo
//! is formatted by CI), not a Rust parser: good enough to gate, simple
//! enough to never need a dependency.

use std::fs;
use std::io;
use std::path::Path;

use super::Finding;

/// No unwrap/expect on serve/replicate connection paths.
pub const LINT_UNWRAP_CONN: &str = "LINT_UNWRAP_CONN";
/// No allocation/locking in the obs hot path outside the allow-list.
pub const LINT_OBS_HOT_PATH: &str = "LINT_OBS_HOT_PATH";
/// No allocation/locking in the per-learn governance budget check.
pub const LINT_GOVERN_HOT_PATH: &str = "LINT_GOVERN_HOT_PATH";
/// Every ObserverSpec kind is fully checkpointable and accounted.
pub const LINT_OBSERVER_SPEC: &str = "LINT_OBSERVER_SPEC";
/// `#![forbid(unsafe_code)]` in every crate root.
pub const LINT_FORBID_UNSAFE: &str = "LINT_FORBID_UNSAFE";
/// Module docs (`//!`) on every public module.
pub const LINT_MODULE_DOCS: &str = "LINT_MODULE_DOCS";

/// Marker comment that exempts a line, with justification:
/// `// audit:allow(rule): why this is fine`.
const ALLOW_MARKER: &str = "audit:allow(";

/// Serve-layer files whose connection-handling code must not panic.
const CONN_FILES: &[&str] = &[
    "rust/src/serve/mod.rs",
    "rust/src/serve/server.rs",
    "rust/src/serve/replicate.rs",
    "rust/src/serve/client.rs",
    "rust/src/serve/fleet.rs",
];

/// Crate roots that must carry `#![forbid(unsafe_code)]`.
const CRATE_ROOTS: &[&str] = &[
    "rust/src/lib.rs",
    "rust/src/main.rs",
    "tools/lint.rs",
    "vendor/anyhow/src/lib.rs",
    "vendor/xla/src/lib.rs",
];

/// Cold-path functions in `obs/mod.rs` allowed to allocate or lock.
/// Everything Mutex-backed routes through the `TraceRing`, which is
/// documented (and gated by `grace_period`) as off the hot path; the
/// rest are readout/exposition functions no recording site calls.
const OBS_COLD_FNS: &[&str] = &[
    "toggle_lock",
    "TraceRing::new",
    "TraceRing::record",
    "TraceRing::events",
    "TraceRing::recent",
    "TraceRing::total",
    "Histogram::snapshot",
    "HistogramSnapshot::empty",
    "HistogramSnapshot::merge",
    "HistogramSnapshot::minus",
    "HistogramSnapshot::quantile",
    "HistogramSnapshot::mean",
    "describe",
    "exposition_of",
    "exposition",
];

/// Tokens that indicate allocation or locking on a source line.
const HOT_PATH_TOKENS: &[&str] = &[
    ".lock(",
    "format!(",
    "String::",
    "Vec::new(",
    "Vec::with_capacity(",
    "vec![",
    "VecDeque::new(",
    "Box::new(",
    ".to_string(",
    ".to_owned(",
    ".to_vec(",
    ".collect(",
];

/// Observer kinds the [`crate::observer::ObserverSpec`] registry can
/// produce, with the file implementing each.
const SPEC_OBSERVERS: &[(&str, &str)] = &[
    ("QuantizationObserver", "rust/src/observer/qo.rs"),
    ("EBst", "rust/src/observer/ebst.rs"),
    ("TruncatedEBst", "rust/src/observer/ebst.rs"),
    ("ExhaustiveObserver", "rust/src/observer/exhaustive.rs"),
];

/// Run every lint rule over the repo rooted at `repo_root`. Findings
/// carry repo-relative paths and 1-based line numbers.
pub fn run(repo_root: &Path) -> io::Result<Vec<Finding>> {
    let mut out = Vec::new();
    lint_unwrap_conn(repo_root, &mut out)?;
    lint_obs_hot_path(repo_root, &mut out)?;
    lint_govern_hot_path(repo_root, &mut out)?;
    lint_observer_spec(repo_root, &mut out)?;
    lint_forbid_unsafe(repo_root, &mut out)?;
    lint_module_docs(repo_root, &mut out)?;
    Ok(out)
}

fn read(repo_root: &Path, rel: &str) -> io::Result<Option<String>> {
    let path = repo_root.join(rel);
    if !path.is_file() {
        return Ok(None);
    }
    fs::read_to_string(path).map(Some)
}

/// Strip a trailing `// …` comment (outside string literals) and return
/// the code part. Good enough for token scanning on rustfmt'd sources.
fn code_part(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_str = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_str => i += 1, // skip the escaped byte
            b'"' => in_str = !in_str,
            b'/' if !in_str && i + 1 < bytes.len() && bytes[i + 1] == b'/' => {
                return &line[..i];
            }
            _ => {}
        }
        i += 1;
    }
    line
}

fn is_comment_only(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("//") || t.is_empty()
}

fn allowed(line: &str, rule: &str) -> bool {
    line.split(ALLOW_MARKER)
        .skip(1)
        .any(|rest| rest.starts_with(rule) || rest.starts_with("all)"))
}

/// Where a file's trailing `#[cfg(test)] mod tests` starts (tests may
/// unwrap freely), or the line count when there is none.
fn tests_start(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| l.trim_start().starts_with("#[cfg(test)]"))
        .unwrap_or(lines.len())
}

fn lint_unwrap_conn(repo_root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    for rel in CONN_FILES {
        let Some(text) = read(repo_root, rel)? else {
            out.push(Finding::at_line(LINT_UNWRAP_CONN, *rel, 1, "connection-path file missing"));
            continue;
        };
        let lines: Vec<&str> = text.lines().collect();
        let end = tests_start(&lines);
        for (i, line) in lines[..end].iter().enumerate() {
            if is_comment_only(line) || allowed(line, "unwrap-conn") {
                continue;
            }
            let code = code_part(line);
            for token in [".unwrap()", ".expect("] {
                if code.contains(token) {
                    out.push(Finding::at_line(
                        LINT_UNWRAP_CONN,
                        *rel,
                        i + 1,
                        format!(
                            "{token} on a connection-handling path: a malformed peer \
                             must not be able to kill this thread"
                        ),
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Extract the implemented type from an `impl … {` header:
/// `impl Metrics {` → `Metrics`, `impl Default for Counter {` → `Counter`.
fn impl_target(line: &str) -> Option<String> {
    let rest = line.trim_start().strip_prefix("impl")?;
    let target = match rest.split(" for ").nth(1) {
        Some(t) => t,
        None => {
            // skip a generics group: `impl<'a> Parser<'a> {`
            let mut t = rest;
            if t.starts_with('<') {
                let mut depth = 0usize;
                let mut end = t.len();
                for (i, c) in t.char_indices() {
                    match c {
                        '<' => depth += 1,
                        '>' => {
                            depth -= 1;
                            if depth == 0 {
                                end = i + 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                t = &t[end..];
            }
            t
        }
    };
    let name: String =
        target.trim_start().chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Extract a declared fn name from a (possibly indented) `fn` line.
fn fn_name(line: &str) -> Option<String> {
    let t = line.trim_start();
    let t = t.strip_prefix("pub ").unwrap_or(t);
    let t = t.strip_prefix("pub(crate) ").unwrap_or(t);
    let t = t.strip_prefix("const ").unwrap_or(t);
    let rest = t.strip_prefix("fn ")?;
    let name: String = rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

fn lint_obs_hot_path(repo_root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    let rel = "rust/src/obs/mod.rs";
    let Some(text) = read(repo_root, rel)? else {
        out.push(Finding::at_line(LINT_OBS_HOT_PATH, rel, 1, "obs/mod.rs missing"));
        return Ok(());
    };
    let lines: Vec<&str> = text.lines().collect();
    let end = tests_start(&lines);
    let mut current_impl: Option<String> = None;
    let mut current_fn: Option<String> = None;
    for (i, line) in lines[..end].iter().enumerate() {
        // context tracking (rustfmt shape: impls at indent 0, their
        // methods at indent 4, closing brace back at column 0)
        if !line.starts_with(' ') {
            if line.starts_with("impl") {
                current_impl = impl_target(line);
                current_fn = None;
            } else if line.starts_with('}') {
                current_impl = None;
                current_fn = None;
            } else if let Some(name) = fn_name(line) {
                current_impl = None;
                current_fn = Some(name);
            }
        } else if line.starts_with("    ") && !line.starts_with("     ") {
            if let Some(name) = fn_name(line) {
                current_fn = Some(match &current_impl {
                    Some(ty) => format!("{ty}::{name}"),
                    None => name,
                });
            }
        }
        if is_comment_only(line) || allowed(line, "obs-hot-path") {
            continue;
        }
        let qualified = current_fn.as_deref().unwrap_or("");
        if OBS_COLD_FNS.contains(&qualified) {
            continue;
        }
        let code = code_part(line);
        for token in HOT_PATH_TOKENS {
            if code.contains(token) {
                out.push(Finding::at_line(
                    LINT_OBS_HOT_PATH,
                    rel,
                    i + 1,
                    format!(
                        "{token:?} in {} — allocation/locking is only allowed in the \
                         cold-path allow-list",
                        if qualified.is_empty() { "module scope" } else { qualified },
                    ),
                ));
            }
        }
    }
    Ok(())
}

/// Functions in `govern/mod.rs` on the per-learn path: consulted before
/// every budget decision, so they must never allocate or lock. Tracked
/// by name so a rename cannot silently retire the rule.
const GOVERN_HOT_FNS: &[&str] =
    &["Governor::new", "Governor::budget", "Governor::enabled", "Governor::over_budget"];

fn lint_govern_hot_path(repo_root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    let rel = "rust/src/govern/mod.rs";
    let Some(text) = read(repo_root, rel)? else {
        out.push(Finding::at_line(LINT_GOVERN_HOT_PATH, rel, 1, "govern/mod.rs missing"));
        return Ok(());
    };
    let lines: Vec<&str> = text.lines().collect();
    let end = tests_start(&lines);
    let mut current_impl: Option<String> = None;
    let mut current_fn: Option<String> = None;
    let mut seen: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
    for (i, line) in lines[..end].iter().enumerate() {
        // same rustfmt-shaped context tracking as the obs hot-path rule
        if !line.starts_with(' ') {
            if line.starts_with("impl") {
                current_impl = impl_target(line);
                current_fn = None;
            } else if line.starts_with('}') {
                current_impl = None;
                current_fn = None;
            } else if let Some(name) = fn_name(line) {
                current_impl = None;
                current_fn = Some(name);
            }
        } else if line.starts_with("    ") && !line.starts_with("     ") {
            if let Some(name) = fn_name(line) {
                current_fn = Some(match &current_impl {
                    Some(ty) => format!("{ty}::{name}"),
                    None => name,
                });
            }
        }
        if is_comment_only(line) || allowed(line, "govern-hot-path") {
            continue;
        }
        let qualified = current_fn.as_deref().unwrap_or("");
        let Some(hot) = GOVERN_HOT_FNS.iter().copied().find(|f| *f == qualified) else {
            continue;
        };
        seen.insert(hot);
        let code = code_part(line);
        for token in HOT_PATH_TOKENS {
            if code.contains(token) {
                out.push(Finding::at_line(
                    LINT_GOVERN_HOT_PATH,
                    rel,
                    i + 1,
                    format!(
                        "{token:?} in {qualified} — the per-learn budget check must stay \
                         one integer compare; only the triggered escalation may allocate"
                    ),
                ));
            }
        }
    }
    for hot in GOVERN_HOT_FNS.iter().copied() {
        if !seen.contains(hot) {
            out.push(Finding::at_line(
                LINT_GOVERN_HOT_PATH,
                rel,
                1,
                format!("hot-path function {hot} not found (renamed without updating the lint?)"),
            ));
        }
    }
    Ok(())
}

fn lint_observer_spec(repo_root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    for (ty, rel) in SPEC_OBSERVERS {
        let Some(text) = read(repo_root, rel)? else {
            out.push(Finding::at_line(
                LINT_OBSERVER_SPEC,
                *rel,
                1,
                format!("file implementing ObserverSpec kind {ty} is missing"),
            ));
            continue;
        };
        let lines: Vec<&str> = text.lines().collect();
        let header = format!("impl AttributeObserver for {ty} ");
        let start = lines.iter().position(|l| {
            l.starts_with(&header) || *l == format!("impl AttributeObserver for {ty} {{")
        });
        let Some(start) = start else {
            out.push(Finding::at_line(
                LINT_OBSERVER_SPEC,
                *rel,
                1,
                format!("no `impl AttributeObserver for {ty}` block"),
            ));
            continue;
        };
        let block_end = lines[start + 1..]
            .iter()
            .position(|l| l.starts_with('}'))
            .map(|off| start + 1 + off)
            .unwrap_or(lines.len());
        for required in ["fn mem_bytes", "fn to_json"] {
            if !lines[start..block_end].iter().any(|l| l.trim_start().contains(required)) {
                out.push(Finding::at_line(
                    LINT_OBSERVER_SPEC,
                    *rel,
                    start + 1,
                    format!(
                        "{ty} is ObserverSpec-registered but its AttributeObserver impl \
                         has no `{required}` override"
                    ),
                ));
            }
        }
        if !lines.iter().any(|l| l.trim_start().contains("fn from_json")) {
            out.push(Finding::at_line(
                LINT_OBSERVER_SPEC,
                *rel,
                start + 1,
                format!("{ty} is ObserverSpec-registered but the file has no `fn from_json`"),
            ));
        }
    }
    Ok(())
}

fn lint_forbid_unsafe(repo_root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    let mut roots: Vec<String> = CRATE_ROOTS.iter().map(|r| r.to_string()).collect();
    // benches are crate roots too
    let bench_dir = repo_root.join("rust/benches");
    if bench_dir.is_dir() {
        let mut benches = Vec::new();
        for entry in fs::read_dir(&bench_dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.ends_with(".rs") {
                benches.push(format!("rust/benches/{name}"));
            }
        }
        benches.sort();
        roots.extend(benches);
    }
    for rel in &roots {
        let Some(text) = read(repo_root, rel)? else {
            out.push(Finding::at_line(LINT_FORBID_UNSAFE, rel.as_str(), 1, "crate root missing"));
            continue;
        };
        if !text.lines().any(|l| l.trim() == "#![forbid(unsafe_code)]") {
            out.push(Finding::at_line(
                LINT_FORBID_UNSAFE,
                rel.as_str(),
                1,
                "crate root lacks #![forbid(unsafe_code)]",
            ));
        }
    }
    Ok(())
}

fn lint_module_docs(repo_root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    // walk `pub mod` declarations starting from the library root
    let mut queue: Vec<(String, String)> = vec![("rust/src/lib.rs".to_string(), String::new())];
    let mut seen = std::collections::BTreeSet::new();
    while let Some((rel, dir)) = queue.pop() {
        if !seen.insert(rel.clone()) {
            continue;
        }
        let Some(text) = read(repo_root, &rel)? else {
            out.push(Finding::at_line(LINT_MODULE_DOCS, rel, 1, "declared module file missing"));
            continue;
        };
        // the file itself must open with `//!` docs (shebang-free Rust)
        let first_code = text.lines().find(|l| !l.trim().is_empty());
        if !matches!(first_code, Some(l) if l.trim_start().starts_with("//!")) {
            out.push(Finding::at_line(
                LINT_MODULE_DOCS,
                rel.clone(),
                1,
                "public module does not start with //! module docs",
            ));
        }
        // resolve child `pub mod x;` declarations
        let base = match rel.strip_suffix("/mod.rs") {
            Some(prefix) => prefix.to_string(),
            None if rel.ends_with("lib.rs") => "rust/src".to_string(),
            None => rel.trim_end_matches(".rs").to_string(),
        };
        let _ = dir;
        for line in text.lines() {
            let t = line.trim();
            let Some(name) = t.strip_prefix("pub mod ").and_then(|r| r.strip_suffix(';')) else {
                continue;
            };
            let flat = format!("{base}/{name}.rs");
            let nested = format!("{base}/{name}/mod.rs");
            if repo_root.join(&flat).is_file() {
                queue.push((flat, String::new()));
            } else if repo_root.join(&nested).is_file() {
                queue.push((nested, String::new()));
            }
            // inline `pub mod name { … }` has no file; its docs are the
            // enclosing file's concern
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The repo root, from the crate manifest dir (tests run in-tree).
    fn repo_root() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf()
    }

    #[test]
    fn repo_is_lint_clean() {
        let findings = run(&repo_root()).unwrap();
        assert!(
            findings.is_empty(),
            "lint findings:\n{}",
            findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
        );
    }

    #[test]
    fn helpers_parse_rustfmt_shapes() {
        assert_eq!(impl_target("impl Metrics {"), Some("Metrics".to_string()));
        assert_eq!(impl_target("impl Default for Counter {"), Some("Counter".to_string()));
        assert_eq!(
            impl_target("impl std::fmt::Display for Finding {"),
            Some("Finding".to_string())
        );
        assert_eq!(impl_target("impl<'a> Parser<'a> {"), Some("Parser".to_string()));
        assert_eq!(fn_name("    pub fn record(&self, v: u64) {"), Some("record".to_string()));
        assert_eq!(fn_name("pub const fn new() -> Self {"), Some("new".to_string()));
        assert_eq!(fn_name("    let x = 1;"), None);
        assert_eq!(code_part(r#"let s = "// not a comment"; // real"#), r#"let s = "// not a comment"; "#);
        assert!(allowed("x.lock(); // audit:allow(obs-hot-path): init only", "obs-hot-path"));
        assert!(!allowed("x.lock(); // audit:allow(unwrap-conn): other rule", "obs-hot-path"));
    }

    #[test]
    fn unwrap_tokens_and_test_boundary() {
        let lines = ["a.unwrap_or_else(|| 0);", "a.unwrap();", "#[cfg(test)]", "b.unwrap();"];
        assert_eq!(tests_start(&lines), 2);
        assert!(!lines[0].contains(".unwrap()"));
        assert!(lines[1].contains(".unwrap()"));
    }
}
