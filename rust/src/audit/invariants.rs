//! Model-invariant verifier: walks a canonical checkpoint document and
//! checks every structural invariant in `docs/INVARIANTS.md`.
//!
//! The verifier re-checks, *independently of the decoders*, everything
//! the system's correctness rests on: arena topology (children strictly
//! after parents — the anti-cycle property `route()` depends on), the
//! paper's QO slot tables (code-sorted, positive-weight, finite mergeable
//! `VarStats`, Σ slot mass = column total; PAPER.md Sec. 3–4), E-BST
//! ordering, leaf linear-model finiteness, the deferred-attempt queue,
//! delta hash-chain continuity, `mem_bytes()` self-consistency, and the
//! binary checkpoint envelope ([`verify_binary`]: framing, trailer
//! integrity, and JSON↔binary decode equivalence).
//! Where a decoder would reject the same corruption, the verifier names
//! the *rule* instead of just erroring — which is what lets a follower
//! report "full resync because ARENA_CHILD_ORDER broke at
//! model.nodes[7]" instead of a bare decode failure.
//!
//! Everything here is read-only and allocation-proportional to the
//! findings; it runs only at boundaries (load / publish / apply) under
//! `debug_assertions`, from the `qostream audit` CLI, and on the
//! already-failed rejection path in [`crate::serve::replicate`].

use crate::common::json::Json;
use crate::persist::delta::{apply, decode_wire_delta, doc_hash, DeltaLog};
use crate::persist::{Model, FORMAT, VERSION};

use super::Finding;

// ---------------------------------------------------------------------------
// Rule ids (catalog: docs/INVARIANTS.md)
// ---------------------------------------------------------------------------

/// Checkpoint envelope: format marker, version, kind tag, model payload.
pub const CKPT_ENVELOPE: &str = "CKPT_ENVELOPE";
/// Tree payload schema: required fields present with the right types.
pub const TREE_SCHEMA: &str = "TREE_SCHEMA";
/// Forest payload schema (ARF/bagging members, ADWIN detectors).
pub const FOREST_SCHEMA: &str = "FOREST_SCHEMA";
/// Node arenas: every child index strictly greater than its parent's.
pub const ARENA_CHILD_ORDER: &str = "ARENA_CHILD_ORDER";
/// Node arenas: every node reachable from the root exactly once.
pub const ARENA_ORPHAN: &str = "ARENA_ORPHAN";
/// Leaf depth equals its arena depth and stays under the configured cap.
pub const ARENA_DEPTH: &str = "ARENA_DEPTH";
/// Deferred-attempt queue entries reference live leaves, without repeats.
pub const PENDING_LEAF: &str = "PENDING_LEAF";
/// Leaf linear models: finite weights/bias with the tree's arity.
pub const LEAF_LINEAR: &str = "LEAF_LINEAR";
/// `VarStats` triples: finite moments, `n ≥ 0`, `m2` non-negative.
pub const VARSTATS_INVALID: &str = "VARSTATS_INVALID";
/// QO slot tables: strictly increasing (hence unique) bucket codes.
pub const QO_SLOT_ORDER: &str = "QO_SLOT_ORDER";
/// QO slots: positive weight and a finite prototype sum.
pub const QO_SLOT_WEIGHT: &str = "QO_SLOT_WEIGHT";
/// QO: Σ slot weight equals the column total once the radius is frozen.
pub const QO_TOTAL_DRIFT: &str = "QO_TOTAL_DRIFT";
/// E-BST: finite keys obeying the binary-search-tree bounds.
pub const EBST_KEY_ORDER: &str = "EBST_KEY_ORDER";
/// Observer payloads: known type tags, labels, monitored-feature lists.
pub const OBSERVER_SCHEMA: &str = "OBSERVER_SCHEMA";
/// Delta chains: versions advance one at a time without gaps.
pub const DELTA_VERSION_ORDER: &str = "DELTA_VERSION_ORDER";
/// Delta chains: every applied patch lands on the advertised hash.
pub const DELTA_HASH_CHAIN: &str = "DELTA_HASH_CHAIN";
/// `mem_bytes()` agrees (within allocator slack) across a codec clone.
pub const MEM_BYTES_STABLE: &str = "MEM_BYTES_STABLE";
/// Binary checkpoint envelope: magic, version, flags, length accounting,
/// a payload that decodes, and a header `doc_hash` equal to the decoded
/// document's canonical-JSON hash (the cross-format equivalence rule).
pub const BIN_ENVELOPE: &str = "BIN_ENVELOPE";
/// Binary checkpoint trailer: end magic present and a trailer payload
/// hash that matches the payload bytes (truncation/bit-rot guard).
pub const BIN_TRAILER: &str = "BIN_TRAILER";
/// Governed checkpoints: the envelope's own memory claim
/// (`mem_budget`/`mem_bytes`, stamped by [`crate::govern`]) must be
/// parseable and must respect the budget it advertises — a file that
/// claims a budget it exceeds convicts itself (docs/MEMORY.md).
pub const GOVERN_BUDGET: &str = "GOVERN_BUDGET";

/// E-BST "no child" sentinel (`u32::MAX`, mirrored from the arena).
const EBST_NONE: u64 = u32::MAX as u64;

/// Relative tolerance for [`QO_TOTAL_DRIFT`]: weights are Poisson draws
/// (integers) or 1.0, so Σ slot `n` and the column total accumulate the
/// same exact additions — the slack only covers merge reordering.
const QO_SUM_RTOL: f64 = 1e-6;

/// Allowed `mem_bytes()` ratio between a live model and its codec clone.
/// Capacity-based accounting differs by `Vec`/`HashMap` growth slack,
/// which amortized doubling bounds well under this.
const MEM_RATIO_MAX: f64 = 4.0;

// ---------------------------------------------------------------------------
// Tolerant scalar readers (the codec's string encodings, no hard errors)
// ---------------------------------------------------------------------------

/// Read an `f64` the way [`crate::persist::codec::pf64`] would.
fn fnum(j: &Json) -> Option<f64> {
    match j {
        Json::Num(v) => Some(*v),
        Json::Str(s) => match s.as_str() {
            "NaN" => Some(f64::NAN),
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            _ => None,
        },
        _ => None,
    }
}

/// Read a `u64` the way [`crate::persist::codec::pu64`] would.
fn unum(j: &Json) -> Option<u64> {
    match j {
        Json::Str(s) => s.parse::<u64>().ok(),
        Json::Num(v) if *v >= 0.0 && *v == v.trunc() && *v <= 2f64.powi(53) => Some(*v as u64),
        _ => None,
    }
}

/// Read an `i64` the way [`crate::persist::codec::pi64`] would.
fn inum(j: &Json) -> Option<i64> {
    match j {
        Json::Str(s) => s.parse::<i64>().ok(),
        Json::Num(v) if *v == v.trunc() && v.abs() <= 2f64.powi(53) => Some(*v as i64),
        _ => None,
    }
}

fn sub(path: &str, key: &str) -> String {
    format!("{path}.{key}")
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// Verify a full checkpoint document ([`Model::to_checkpoint`] layout).
/// Returns every finding, empty on a clean document.
pub fn verify_checkpoint(doc: &Json) -> Vec<Finding> {
    let mut out = Vec::new();
    if doc.as_obj().is_none() {
        out.push(Finding::new(CKPT_ENVELOPE, "", "checkpoint is not a JSON object"));
        return out;
    }
    match doc.get("format").and_then(Json::as_str) {
        Some(f) if f == FORMAT => {}
        Some(f) => out.push(Finding::new(
            CKPT_ENVELOPE,
            "format",
            format!("expected {FORMAT:?}, got {f:?}"),
        )),
        None => out.push(Finding::new(CKPT_ENVELOPE, "format", "missing format marker")),
    }
    match doc.get("version").and_then(unum) {
        Some(v) if v == VERSION => {}
        Some(v) => out.push(Finding::new(
            CKPT_ENVELOPE,
            "version",
            format!("expected version {VERSION}, got {v}"),
        )),
        None => out.push(Finding::new(CKPT_ENVELOPE, "version", "missing or non-u64 version")),
    }
    // governed envelopes carry their own budget claim; hold the file to it
    match crate::govern::governed_claim(doc) {
        Ok(None) => {}
        Ok(Some((budget, claimed))) => {
            if budget > 0 && claimed > budget {
                out.push(Finding::new(
                    GOVERN_BUDGET,
                    "mem_bytes",
                    format!("checkpoint claims {claimed} B under a {budget} B budget"),
                ));
            }
        }
        Err(e) => out.push(Finding::new(GOVERN_BUDGET, "mem_budget", format!("{e}"))),
    }
    let Some(model) = doc.get("model") else {
        out.push(Finding::new(CKPT_ENVELOPE, "model", "missing model payload"));
        return out;
    };
    match doc.get("kind").and_then(Json::as_str) {
        Some("tree") => verify_tree(model, "model", &mut out),
        Some("arf") => verify_arf(model, &mut out),
        Some("bagging") => verify_bagging(model, &mut out),
        Some(other) => {
            out.push(Finding::new(CKPT_ENVELOPE, "kind", format!("unknown kind {other:?}")));
        }
        None => out.push(Finding::new(CKPT_ENVELOPE, "kind", "missing kind tag")),
    }
    out
}

/// Verify a live model end to end: encode, run [`verify_checkpoint`],
/// then prove `mem_bytes()` is codec-stable ([`MEM_BYTES_STABLE`]) by
/// comparing the live accounting against a `clone_via_codec` restore.
pub fn verify_model(model: &Model) -> Vec<Finding> {
    let mut out = Vec::new();
    let doc = match model.to_checkpoint() {
        Ok(doc) => doc,
        Err(e) => {
            out.push(Finding::new(CKPT_ENVELOPE, "model", format!("encode failed: {e}")));
            return out;
        }
    };
    out.extend(verify_checkpoint(&doc));
    match model.clone_via_codec() {
        Ok(clone) => {
            let live = model.mem_bytes();
            let restored = clone.mem_bytes();
            let ratio_ok = live > 0
                && restored > 0
                && live as f64 / restored as f64 <= MEM_RATIO_MAX
                && restored as f64 / live as f64 <= MEM_RATIO_MAX;
            if !ratio_ok {
                out.push(Finding::new(
                    MEM_BYTES_STABLE,
                    "model",
                    format!(
                        "mem_bytes {live} live vs {restored} restored \
                         (expected both nonzero within {MEM_RATIO_MAX}x)"
                    ),
                ));
            }
        }
        Err(e) => {
            out.push(Finding::new(CKPT_ENVELOPE, "model", format!("codec round-trip failed: {e}")));
        }
    }
    out
}

/// Verify a wire-delta chain (`{"from","to","hash","ops"}` records, the
/// `repl_sync` payload shape) applied on top of `base`: versions must
/// advance one at a time without gaps ([`DELTA_VERSION_ORDER`]), every
/// apply must land on the advertised hash ([`DELTA_HASH_CHAIN`]), and
/// the final document must itself pass [`verify_checkpoint`].
pub fn verify_delta_chain(base: &Json, deltas: &[Json]) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut doc = base.clone();
    let mut prev_to: Option<u64> = None;
    for (i, wire) in deltas.iter().enumerate() {
        let path = format!("deltas[{i}]");
        let (from, to, hash, ops) = match decode_wire_delta(wire) {
            Ok(decoded) => decoded,
            Err(e) => {
                out.push(Finding::new(DELTA_HASH_CHAIN, path, format!("malformed wire delta: {e}")));
                return out;
            }
        };
        if to != from + 1 {
            out.push(Finding::new(
                DELTA_VERSION_ORDER,
                path.clone(),
                format!("expected to = from + 1, got {from} -> {to}"),
            ));
        }
        if let Some(prev) = prev_to {
            if from != prev {
                out.push(Finding::new(
                    DELTA_VERSION_ORDER,
                    path.clone(),
                    format!("chain gap: previous delta ended at {prev}, this one starts at {from}"),
                ));
            }
        }
        prev_to = Some(to);
        match apply(&doc, ops) {
            Ok(next) => {
                let got = doc_hash(&next);
                if got != hash {
                    out.push(Finding::new(
                        DELTA_HASH_CHAIN,
                        path,
                        format!("version {to}: applied hash {got:#x} != advertised {hash:#x}"),
                    ));
                    return out;
                }
                doc = next;
            }
            Err(e) => {
                out.push(Finding::new(DELTA_HASH_CHAIN, path, format!("apply failed: {e}")));
                return out;
            }
        }
    }
    out.extend(verify_checkpoint(&doc));
    out
}

/// Verify an in-memory [`DeltaLog`]'s shape: contiguous entry versions
/// ending at the head, and a head hash that matches the head document.
pub fn verify_log(log: &DeltaLog) -> Vec<Finding> {
    let mut out = Vec::new();
    let head = doc_hash(log.doc());
    if head != log.hash() {
        out.push(Finding::new(
            DELTA_HASH_CHAIN,
            "log.head",
            format!("head document hashes to {head:#x} but the log advertises {:#x}", log.hash()),
        ));
    }
    let mut prev_to: Option<u64> = None;
    for entry in log.entries() {
        if let Some(prev) = prev_to {
            if entry.from != prev {
                out.push(Finding::new(
                    DELTA_VERSION_ORDER,
                    format!("log.entries[from={}]", entry.from),
                    format!("expected from = {prev} after the previous entry"),
                ));
            }
        }
        prev_to = Some(entry.from + 1);
    }
    if let Some(prev) = prev_to {
        if prev != log.version() {
            out.push(Finding::new(
                DELTA_VERSION_ORDER,
                "log.head",
                format!("entries end at version {prev} but the head is {}", log.version()),
            ));
        }
    }
    out
}

/// Verify a **binary** checkpoint ([`crate::persist::binary`] envelope)
/// end to end, independently of the decoder: envelope framing
/// ([`BIN_ENVELOPE`]), trailer integrity ([`BIN_TRAILER`]), then decode
/// the payload and require JSON↔binary equivalence — the header's
/// `doc_hash` must equal the decoded document's canonical-JSON hash —
/// before handing the document to [`verify_checkpoint`]. The framing
/// checks re-read the raw bytes here rather than trusting
/// `binary::read_envelope`, so a decoder bug cannot mask a corrupt file.
pub fn verify_binary(bytes: &[u8]) -> Vec<Finding> {
    use crate::persist::binary::{
        self, BIN_VERSION, HEADER_LEN, MAGIC, TRAILER_LEN, TRAILER_MAGIC,
    };
    use std::hash::Hasher;

    let mut out = Vec::new();
    if bytes.len() < HEADER_LEN + TRAILER_LEN {
        out.push(Finding::new(
            BIN_ENVELOPE,
            "header",
            format!(
                "file is {} bytes; the envelope alone needs {}",
                bytes.len(),
                HEADER_LEN + TRAILER_LEN
            ),
        ));
        return out;
    }
    if &bytes[0..4] != MAGIC {
        out.push(Finding::new(
            BIN_ENVELOPE,
            "header.magic",
            format!("expected {MAGIC:?}, got {:?}", &bytes[0..4]),
        ));
        return out;
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != BIN_VERSION {
        out.push(Finding::new(
            BIN_ENVELOPE,
            "header.version",
            format!("expected binary version {BIN_VERSION}, got {version}"),
        ));
        return out;
    }
    let flags = u16::from_le_bytes([bytes[6], bytes[7]]);
    if flags != 0 {
        out.push(Finding::new(
            BIN_ENVELOPE,
            "header.flags",
            format!("reserved flags must be 0, got {flags:#06x}"),
        ));
    }
    let header_doc_hash =
        u64::from_le_bytes(bytes[8..16].try_into().expect("len 8"));
    let payload_len = u64::from_le_bytes(bytes[16..24].try_into().expect("len 8"));
    let actual_len = (bytes.len() - HEADER_LEN - TRAILER_LEN) as u64;
    if payload_len != actual_len {
        out.push(Finding::new(
            BIN_ENVELOPE,
            "header.payload_len",
            format!("header claims {payload_len} payload bytes, file holds {actual_len}"),
        ));
        return out;
    }
    let payload = &bytes[HEADER_LEN..bytes.len() - TRAILER_LEN];
    let trailer = &bytes[bytes.len() - TRAILER_LEN..];
    if &trailer[0..4] != TRAILER_MAGIC {
        out.push(Finding::new(
            BIN_TRAILER,
            "trailer.magic",
            format!("expected {TRAILER_MAGIC:?}, got {:?}", &trailer[0..4]),
        ));
    }
    let trailer_hash = u64::from_le_bytes(trailer[4..12].try_into().expect("len 8"));
    let computed = {
        let mut h = crate::common::fxhash::FxHasher::default();
        h.write(payload);
        h.finish()
    };
    if trailer_hash != computed {
        out.push(Finding::new(
            BIN_TRAILER,
            "trailer.payload_hash",
            format!("trailer advertises {trailer_hash:#018x}, payload hashes to {computed:#018x}"),
        ));
    }
    let doc = match binary::decode_value(payload) {
        Ok(doc) => doc,
        Err(e) => {
            out.push(Finding::new(
                BIN_ENVELOPE,
                "payload",
                format!("payload does not decode: {e}"),
            ));
            return out;
        }
    };
    let canonical = doc_hash(&doc);
    if canonical != header_doc_hash {
        out.push(Finding::new(
            BIN_ENVELOPE,
            "header.doc_hash",
            format!(
                "header advertises {header_doc_hash:#018x} but the decoded document's \
                 canonical JSON hashes to {canonical:#018x}"
            ),
        ));
    }
    out.extend(verify_checkpoint(&doc));
    out
}

/// One-line explanation of the *first* invariant a document breaks
/// (`"RULE at path"`), or `None` when it is clean. The replication layer
/// appends this to rejection errors so `last_resync_cause` names the
/// broken invariant, not just the decode symptom.
pub fn explain(doc: &Json) -> Option<String> {
    verify_checkpoint(doc)
        .first()
        .map(|f| format!("{} at {}", f.rule, if f.path.is_empty() { "root" } else { &f.path }))
}

// ---------------------------------------------------------------------------
// Tree payload
// ---------------------------------------------------------------------------

/// What a structural pass learned about one arena node.
enum NodeShape {
    Leaf { declared_depth: Option<usize> },
    Split { left: usize, right: usize },
    Bad,
}

fn verify_tree(j: &Json, path: &str, out: &mut Vec<Finding>) {
    let n_features = match j.get("n_features").and_then(unum) {
        Some(n) => Some(n as usize),
        None => {
            out.push(Finding::new(
                TREE_SCHEMA,
                sub(path, "n_features"),
                "missing or non-u64 n_features",
            ));
            None
        }
    };
    match j.get("observer").and_then(Json::as_str) {
        Some(label) => {
            if crate::observer::ObserverSpec::from_label(label).is_none() {
                out.push(Finding::new(
                    OBSERVER_SCHEMA,
                    sub(path, "observer"),
                    format!("unknown observer label {label:?}"),
                ));
            }
        }
        None => out.push(Finding::new(TREE_SCHEMA, sub(path, "observer"), "missing observer label")),
    }
    match j.get("criterion").and_then(Json::as_str) {
        Some("variance-reduction") | Some("sd-reduction") => {}
        Some(other) => out.push(Finding::new(
            TREE_SCHEMA,
            sub(path, "criterion"),
            format!("unknown split criterion {other:?}"),
        )),
        None => out.push(Finding::new(TREE_SCHEMA, sub(path, "criterion"), "missing criterion")),
    }
    if j.get("n_splits").and_then(unum).is_none() {
        out.push(Finding::new(TREE_SCHEMA, sub(path, "n_splits"), "missing or non-u64 n_splits"));
    }
    verify_rng(j.get("rng"), &sub(path, "rng"), TREE_SCHEMA, out);
    let max_depth = j
        .get("options")
        .and_then(|o| o.get("max_depth"))
        .and_then(unum)
        .map(|d| d as usize);
    if max_depth.is_none() {
        out.push(Finding::new(
            TREE_SCHEMA,
            sub(path, "options.max_depth"),
            "missing or non-u64 max_depth",
        ));
    }
    let Some(nodes) = j.get("nodes").and_then(Json::as_arr) else {
        out.push(Finding::new(TREE_SCHEMA, sub(path, "nodes"), "missing node arena"));
        return;
    };
    if nodes.is_empty() {
        out.push(Finding::new(TREE_SCHEMA, sub(path, "nodes"), "empty node arena"));
        return;
    }
    let n = nodes.len();

    // structural pass over the arena
    let mut shapes = Vec::with_capacity(n);
    for (idx, item) in nodes.iter().enumerate() {
        let node_path = format!("{path}.nodes[{idx}]");
        if let Some(leaf) = item.get("leaf") {
            let declared_depth =
                verify_leaf(leaf, &format!("{node_path}.leaf"), n_features, out);
            shapes.push(NodeShape::Leaf { declared_depth });
        } else if let Some(split) = item.get("split") {
            let split_path = format!("{node_path}.split");
            match (j.get("n_features").and_then(unum), split.get("feature").and_then(unum)) {
                (Some(nf), Some(f)) if f >= nf => out.push(Finding::new(
                    TREE_SCHEMA,
                    sub(&split_path, "feature"),
                    format!("split feature {f} out of range (n_features {nf})"),
                )),
                (_, Some(_)) => {}
                (_, None) => out.push(Finding::new(
                    TREE_SCHEMA,
                    sub(&split_path, "feature"),
                    "missing or non-u64 feature",
                )),
            }
            match split.get("threshold").and_then(fnum) {
                Some(t) if t.is_finite() => {}
                Some(t) => out.push(Finding::new(
                    TREE_SCHEMA,
                    sub(&split_path, "threshold"),
                    format!("non-finite threshold {t}"),
                )),
                None => out.push(Finding::new(
                    TREE_SCHEMA,
                    sub(&split_path, "threshold"),
                    "missing threshold",
                )),
            }
            let left = split.get("left").and_then(unum).map(|v| v as usize);
            let right = split.get("right").and_then(unum).map(|v| v as usize);
            let (Some(left), Some(right)) = (left, right) else {
                out.push(Finding::new(TREE_SCHEMA, split_path, "missing child indices"));
                shapes.push(NodeShape::Bad);
                continue;
            };
            let mut ok = true;
            for (name, child) in [("left", left), ("right", right)] {
                if child >= n {
                    out.push(Finding::new(
                        ARENA_CHILD_ORDER,
                        sub(&split_path, name),
                        format!("child {child} out of range (arena has {n} nodes)"),
                    ));
                    ok = false;
                } else if child <= idx {
                    out.push(Finding::new(
                        ARENA_CHILD_ORDER,
                        sub(&split_path, name),
                        format!("child {child} must come after its parent {idx}"),
                    ));
                    ok = false;
                }
            }
            if left == right {
                out.push(Finding::new(
                    ARENA_CHILD_ORDER,
                    split_path,
                    format!("left and right both point at node {left}"),
                ));
                ok = false;
            }
            shapes.push(if ok { NodeShape::Split { left, right } } else { NodeShape::Bad });
        } else {
            out.push(Finding::new(TREE_SCHEMA, node_path, "expected a \"leaf\" or \"split\" node"));
            shapes.push(NodeShape::Bad);
        }
    }

    let root = match j.get("root").and_then(unum).map(|v| v as usize) {
        Some(root) if root < n => root,
        Some(root) => {
            out.push(Finding::new(
                TREE_SCHEMA,
                sub(path, "root"),
                format!("root {root} out of range (arena has {n} nodes)"),
            ));
            return;
        }
        None => {
            out.push(Finding::new(TREE_SCHEMA, sub(path, "root"), "missing or non-u64 root"));
            return;
        }
    };

    // reachability + depth in one forward pass: children always come
    // after their parent, so ascending order visits parents first
    let mut depth: Vec<Option<usize>> = vec![None; n];
    depth[root] = Some(0);
    for idx in 0..n {
        let NodeShape::Split { left, right } = shapes[idx] else { continue };
        let Some(d) = depth[idx] else { continue };
        for child in [left, right] {
            if depth[child].is_some() {
                out.push(Finding::new(
                    ARENA_ORPHAN,
                    format!("{path}.nodes[{child}]"),
                    "node is referenced by more than one parent",
                ));
            } else {
                depth[child] = Some(d + 1);
            }
        }
    }
    for (idx, d) in depth.iter().enumerate() {
        let Some(d) = d else {
            out.push(Finding::new(
                ARENA_ORPHAN,
                format!("{path}.nodes[{idx}]"),
                "node is unreachable from the root",
            ));
            continue;
        };
        if let Some(cap) = max_depth {
            if *d > cap {
                out.push(Finding::new(
                    ARENA_DEPTH,
                    format!("{path}.nodes[{idx}]"),
                    format!("node sits at depth {d}, beyond max_depth {cap}"),
                ));
            }
        }
        if let NodeShape::Leaf { declared_depth: Some(dd) } = shapes[idx] {
            if dd != *d {
                out.push(Finding::new(
                    ARENA_DEPTH,
                    format!("{path}.nodes[{idx}].leaf.depth"),
                    format!("leaf declares depth {dd} but sits at arena depth {d}"),
                ));
            }
        }
    }

    // deferred-attempt queue
    match j.get("pending").and_then(Json::as_arr) {
        Some(pending) => {
            let mut seen = vec![false; n];
            for (i, item) in pending.iter().enumerate() {
                let entry_path = format!("{path}.pending[{i}]");
                let Some(idx) = unum(item).map(|v| v as usize) else {
                    out.push(Finding::new(PENDING_LEAF, entry_path, "non-u64 queue entry"));
                    continue;
                };
                if idx >= n {
                    out.push(Finding::new(
                        PENDING_LEAF,
                        entry_path,
                        format!("queued node {idx} out of range (arena has {n} nodes)"),
                    ));
                    continue;
                }
                if !matches!(shapes[idx], NodeShape::Leaf { .. }) {
                    out.push(Finding::new(
                        PENDING_LEAF,
                        entry_path,
                        format!("queued node {idx} is not a leaf"),
                    ));
                }
                if seen[idx] {
                    out.push(Finding::new(
                        PENDING_LEAF,
                        entry_path,
                        format!("node {idx} queued more than once"),
                    ));
                }
                seen[idx] = true;
            }
        }
        None => out.push(Finding::new(TREE_SCHEMA, sub(path, "pending"), "missing pending queue")),
    }
}

/// Verify one leaf payload; returns the declared depth when readable.
fn verify_leaf(j: &Json, path: &str, n_features: Option<usize>, out: &mut Vec<Finding>) -> Option<usize> {
    verify_varstats(j.get("stats"), &sub(path, "stats"), out);
    match j.get("kind").and_then(Json::as_str) {
        Some("mean") | Some("linear") | Some("adaptive") => {}
        Some(other) => out.push(Finding::new(
            TREE_SCHEMA,
            sub(path, "kind"),
            format!("unknown leaf model kind {other:?}"),
        )),
        None => out.push(Finding::new(TREE_SCHEMA, sub(path, "kind"), "missing leaf model kind")),
    }
    for key in ["mean_err", "lin_err", "weight_since_attempt"] {
        match j.get(key).and_then(fnum) {
            Some(v) if v.is_finite() && v >= 0.0 => {}
            Some(v) => out.push(Finding::new(
                TREE_SCHEMA,
                sub(path, key),
                format!("expected a finite non-negative value, got {v}"),
            )),
            None => out.push(Finding::new(TREE_SCHEMA, sub(path, key), "missing value")),
        }
    }

    // monitored feature list
    let monitored_len = match j.get("monitored").and_then(Json::as_arr) {
        Some(monitored) => {
            let mut seen = std::collections::BTreeSet::new();
            for (i, item) in monitored.iter().enumerate() {
                let entry_path = format!("{path}.monitored[{i}]");
                let Some(f) = unum(item).map(|v| v as usize) else {
                    out.push(Finding::new(OBSERVER_SCHEMA, entry_path, "non-u64 feature index"));
                    continue;
                };
                if let Some(nf) = n_features {
                    if f >= nf {
                        out.push(Finding::new(
                            OBSERVER_SCHEMA,
                            entry_path,
                            format!("monitored feature {f} out of range (n_features {nf})"),
                        ));
                    }
                }
                if !seen.insert(f) {
                    out.push(Finding::new(
                        OBSERVER_SCHEMA,
                        entry_path,
                        format!("feature {f} monitored more than once"),
                    ));
                }
            }
            Some(monitored.len())
        }
        None => {
            out.push(Finding::new(
                OBSERVER_SCHEMA,
                sub(path, "monitored"),
                "missing monitored-feature list",
            ));
            None
        }
    };

    // observers: null = frozen leaf; otherwise one per monitored feature
    match j.get("observers") {
        Some(Json::Null) => {}
        Some(Json::Arr(observers)) => {
            if let Some(m) = monitored_len {
                if observers.len() != m {
                    out.push(Finding::new(
                        OBSERVER_SCHEMA,
                        sub(path, "observers"),
                        format!("{} observers for {m} monitored features", observers.len()),
                    ));
                }
            }
            for (i, item) in observers.iter().enumerate() {
                verify_observer(item, &format!("{path}.observers[{i}]"), out);
            }
        }
        Some(_) => out.push(Finding::new(
            OBSERVER_SCHEMA,
            sub(path, "observers"),
            "expected null or an observer array",
        )),
        None => out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "observers"), "missing observers")),
    }

    match j.get("linear") {
        Some(linear) => verify_linear(linear, &sub(path, "linear"), n_features, out),
        None => out.push(Finding::new(LEAF_LINEAR, sub(path, "linear"), "missing linear model")),
    }

    match j.get("depth").and_then(unum) {
        Some(d) => Some(d as usize),
        None => {
            out.push(Finding::new(TREE_SCHEMA, sub(path, "depth"), "missing or non-u64 depth"));
            None
        }
    }
}

fn verify_linear(j: &Json, path: &str, n_features: Option<usize>, out: &mut Vec<Finding>) {
    match j.get("weights").and_then(Json::as_arr) {
        Some(weights) => {
            if let Some(nf) = n_features {
                if weights.len() != nf {
                    out.push(Finding::new(
                        LEAF_LINEAR,
                        sub(path, "weights"),
                        format!("{} weights for {nf} features", weights.len()),
                    ));
                }
            }
            for (i, w) in weights.iter().enumerate() {
                match fnum(w) {
                    Some(v) if v.is_finite() => {}
                    Some(v) => out.push(Finding::new(
                        LEAF_LINEAR,
                        format!("{path}.weights[{i}]"),
                        format!("non-finite weight {v}"),
                    )),
                    None => out.push(Finding::new(
                        LEAF_LINEAR,
                        format!("{path}.weights[{i}]"),
                        "non-numeric weight",
                    )),
                }
            }
            if let Some(stats) = j.get("feature_stats").and_then(Json::as_arr) {
                if stats.len() != weights.len() {
                    out.push(Finding::new(
                        LEAF_LINEAR,
                        sub(path, "feature_stats"),
                        format!("{} feature_stats for {} weights", stats.len(), weights.len()),
                    ));
                }
                for (i, s) in stats.iter().enumerate() {
                    verify_varstats(Some(s), &format!("{path}.feature_stats[{i}]"), out);
                }
            } else {
                out.push(Finding::new(LEAF_LINEAR, sub(path, "feature_stats"), "missing"));
            }
        }
        None => out.push(Finding::new(LEAF_LINEAR, sub(path, "weights"), "missing weight vector")),
    }
    for key in ["bias", "lr"] {
        match j.get(key).and_then(fnum) {
            Some(v) if v.is_finite() => {}
            Some(v) => out.push(Finding::new(
                LEAF_LINEAR,
                sub(path, key),
                format!("non-finite {key} {v}"),
            )),
            None => out.push(Finding::new(LEAF_LINEAR, sub(path, key), format!("missing {key}"))),
        }
    }
    verify_varstats(j.get("target_stats"), &sub(path, "target_stats"), out);
}

// ---------------------------------------------------------------------------
// VarStats and observers
// ---------------------------------------------------------------------------

/// Verify a `[n, mean, m2]` triple; returns the parsed `n` when clean.
fn verify_varstats(j: Option<&Json>, path: &str, out: &mut Vec<Finding>) -> Option<f64> {
    let Some(items) = j.and_then(Json::as_arr) else {
        out.push(Finding::new(VARSTATS_INVALID, path, "expected a [n, mean, m2] triple"));
        return None;
    };
    if items.len() != 3 {
        out.push(Finding::new(
            VARSTATS_INVALID,
            path,
            format!("expected 3 elements, got {}", items.len()),
        ));
        return None;
    }
    let (Some(n), Some(mean), Some(m2)) = (fnum(&items[0]), fnum(&items[1]), fnum(&items[2]))
    else {
        out.push(Finding::new(VARSTATS_INVALID, path, "non-numeric moment"));
        return None;
    };
    if !n.is_finite() || n < 0.0 {
        out.push(Finding::new(
            VARSTATS_INVALID,
            path,
            format!("weight n must be finite and >= 0, got {n}"),
        ));
        return None;
    }
    if !mean.is_finite() {
        out.push(Finding::new(VARSTATS_INVALID, path, format!("non-finite mean {mean}")));
        return None;
    }
    // the paper's subtract extension can leave a tiny negative m2 from
    // float cancellation (variance() clamps it); only clear negatives
    // are corruption
    if !m2.is_finite() || m2 < -1e-6 * n.max(1.0) {
        out.push(Finding::new(
            VARSTATS_INVALID,
            path,
            format!("second moment m2 must be finite and >= 0, got {m2}"),
        ));
        return None;
    }
    Some(n)
}

fn verify_observer(j: &Json, path: &str, out: &mut Vec<Finding>) {
    match j.get("type").and_then(Json::as_str) {
        Some("qo") => verify_qo(j, path, out),
        Some("ebst") => verify_ebst(j, path, out),
        Some("tebst") => {
            match j.get("decimals").and_then(unum) {
                Some(d) if d <= 300 => {}
                Some(d) => out.push(Finding::new(
                    OBSERVER_SCHEMA,
                    sub(path, "decimals"),
                    format!("{d} decimal places is not representable"),
                )),
                None => out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "decimals"), "missing")),
            }
            match j.get("inner") {
                Some(inner) => verify_ebst(inner, &sub(path, "inner"), out),
                None => out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "inner"), "missing")),
            }
        }
        Some("exhaustive") => {
            verify_varstats(j.get("total"), &sub(path, "total"), out);
            match j.get("points").and_then(Json::as_arr) {
                Some(points) => {
                    for (i, p) in points.iter().enumerate() {
                        let ok = p
                            .as_arr()
                            .map(|t| t.len() == 3 && t.iter().all(|v| fnum(v).is_some()))
                            .unwrap_or(false);
                        if !ok {
                            out.push(Finding::new(
                                OBSERVER_SCHEMA,
                                format!("{path}.points[{i}]"),
                                "expected an [x, y, w] triple",
                            ));
                        }
                    }
                }
                None => out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "points"), "missing")),
            }
        }
        Some(other) => out.push(Finding::new(
            OBSERVER_SCHEMA,
            sub(path, "type"),
            format!("unknown observer type {other:?}"),
        )),
        None => out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "type"), "missing type tag")),
    }
}

fn verify_qo(j: &Json, path: &str, out: &mut Vec<Finding>) {
    match j.get("policy") {
        Some(p) if p.get("fixed").is_some() || p.get("std").is_some() => {}
        Some(_) => out.push(Finding::new(
            OBSERVER_SCHEMA,
            sub(path, "policy"),
            "expected a \"fixed\" or \"std\" radius policy",
        )),
        None => out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "policy"), "missing")),
    }
    let frozen = match j.get("state") {
        Some(s) if s.get("frozen").is_some() => {
            match s.get("frozen").and_then(fnum) {
                Some(r) if r.is_finite() => {}
                _ => out.push(Finding::new(
                    OBSERVER_SCHEMA,
                    sub(path, "state.frozen"),
                    "frozen radius must be a finite number",
                )),
            }
            true
        }
        Some(s) if s.get("warming").is_some() => false,
        Some(_) => {
            out.push(Finding::new(
                OBSERVER_SCHEMA,
                sub(path, "state"),
                "expected a \"frozen\" or \"warming\" radius state",
            ));
            false
        }
        None => {
            out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "state"), "missing"));
            false
        }
    };
    match j.get("strategy").and_then(Json::as_str) {
        Some("prototype") | Some("grid") => {}
        Some(other) => out.push(Finding::new(
            OBSERVER_SCHEMA,
            sub(path, "strategy"),
            format!("unknown split-point strategy {other:?}"),
        )),
        None => out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "strategy"), "missing")),
    }
    let total_n = verify_varstats(j.get("total"), &sub(path, "total"), out);

    let Some(slots) = j.get("slots").and_then(Json::as_arr) else {
        out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "slots"), "missing slot table"));
        return;
    };
    let mut prev_code: Option<i64> = None;
    let mut slot_sum = 0.0;
    let mut all_parsed = true;
    for (i, item) in slots.iter().enumerate() {
        let slot_path = format!("{path}.slots[{i}]");
        let Some(entry) = item.as_arr().filter(|e| e.len() == 3) else {
            out.push(Finding::new(
                OBSERVER_SCHEMA,
                slot_path,
                "expected a [code, sum_x, stats] triple",
            ));
            all_parsed = false;
            continue;
        };
        match inum(&entry[0]) {
            Some(code) => {
                if let Some(prev) = prev_code {
                    if code <= prev {
                        out.push(Finding::new(
                            QO_SLOT_ORDER,
                            slot_path.clone(),
                            format!("code {code} after {prev}: codes must strictly increase"),
                        ));
                    }
                }
                prev_code = Some(code);
            }
            None => {
                out.push(Finding::new(OBSERVER_SCHEMA, slot_path.clone(), "non-i64 bucket code"));
                all_parsed = false;
            }
        }
        match fnum(&entry[1]) {
            Some(sum_x) if sum_x.is_finite() => {}
            _ => out.push(Finding::new(
                QO_SLOT_WEIGHT,
                format!("{slot_path}[1]"),
                "prototype sum_x must be a finite number",
            )),
        }
        match verify_varstats(Some(&entry[2]), &format!("{slot_path}[2]"), out) {
            Some(n) if n > 0.0 => slot_sum += n,
            Some(n) => out.push(Finding::new(
                QO_SLOT_WEIGHT,
                format!("{slot_path}[2]"),
                format!("slot weight must be > 0, got {n}"),
            )),
            None => all_parsed = false,
        }
    }
    // Paper Sec. 3: slots partition the column, so once the radius is
    // frozen (no points hiding in a warmup buffer) their weights must
    // sum back to the column total exactly (mod merge reordering)
    if frozen && all_parsed {
        if let Some(total) = total_n {
            if (slot_sum - total).abs() > QO_SUM_RTOL * total.max(1.0) {
                out.push(Finding::new(
                    QO_TOTAL_DRIFT,
                    sub(path, "slots"),
                    format!("slot weights sum to {slot_sum} but the column total is {total}"),
                ));
            }
        }
    }
}

fn verify_ebst(j: &Json, path: &str, out: &mut Vec<Finding>) {
    verify_varstats(j.get("total"), &sub(path, "total"), out);
    let Some(nodes) = j.get("nodes").and_then(Json::as_arr) else {
        out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "nodes"), "missing node arena"));
        return;
    };
    let n = nodes.len();
    let mut keys: Vec<Option<f64>> = Vec::with_capacity(n);
    let mut children: Vec<Option<(u64, u64)>> = Vec::with_capacity(n);
    for (idx, item) in nodes.iter().enumerate() {
        let node_path = format!("{path}.nodes[{idx}]");
        let Some(entry) = item.as_arr().filter(|e| e.len() == 4) else {
            out.push(Finding::new(
                OBSERVER_SCHEMA,
                node_path,
                "expected a [key, stats, left, right] row",
            ));
            keys.push(None);
            children.push(None);
            continue;
        };
        match fnum(&entry[0]) {
            Some(key) if key.is_finite() => keys.push(Some(key)),
            Some(key) => {
                out.push(Finding::new(
                    EBST_KEY_ORDER,
                    format!("{node_path}[0]"),
                    format!("non-finite key {key}"),
                ));
                keys.push(None);
            }
            None => {
                out.push(Finding::new(OBSERVER_SCHEMA, format!("{node_path}[0]"), "non-numeric key"));
                keys.push(None);
            }
        }
        verify_varstats(Some(&entry[1]), &format!("{node_path}[1]"), out);
        let (left, right) = (unum(&entry[2]), unum(&entry[3]));
        let (Some(left), Some(right)) = (left, right) else {
            out.push(Finding::new(
                OBSERVER_SCHEMA,
                node_path,
                "non-u64 child index",
            ));
            children.push(None);
            continue;
        };
        let mut ok = true;
        for (name, child) in [("left", left), ("right", right)] {
            if child != EBST_NONE && (child as usize >= n || child as usize <= idx) {
                out.push(Finding::new(
                    ARENA_CHILD_ORDER,
                    format!("{node_path}.{name}"),
                    format!("ebst child {child} out of order (parent {idx}, arena {n})"),
                ));
                ok = false;
            }
        }
        if left == right && left != EBST_NONE {
            out.push(Finding::new(
                ARENA_CHILD_ORDER,
                node_path,
                format!("left and right both point at node {left}"),
            ));
            ok = false;
        }
        children.push(if ok { Some((left, right)) } else { None });
    }

    let root = match j.get("root").and_then(unum) {
        Some(root) => root,
        None => {
            out.push(Finding::new(OBSERVER_SCHEMA, sub(path, "root"), "missing or non-u64 root"));
            return;
        }
    };
    if root == EBST_NONE {
        if n != 0 {
            out.push(Finding::new(
                ARENA_ORPHAN,
                sub(path, "root"),
                format!("root is NONE but the arena holds {n} nodes"),
            ));
        }
        return;
    }
    if root as usize >= n {
        out.push(Finding::new(
            OBSERVER_SCHEMA,
            sub(path, "root"),
            format!("root {root} out of range (arena has {n} nodes)"),
        ));
        return;
    }

    // BST bounds walk: every key strictly inside its inherited interval
    // (equal keys are absorbed at the existing node, never re-inserted)
    let mut visited = vec![false; n];
    let mut stack: Vec<(usize, Option<f64>, Option<f64>)> = vec![(root as usize, None, None)];
    while let Some((idx, lo, hi)) = stack.pop() {
        if visited[idx] {
            // child>parent precludes cycles; a repeat means double-reference
            out.push(Finding::new(
                ARENA_ORPHAN,
                format!("{path}.nodes[{idx}]"),
                "node is referenced by more than one parent",
            ));
            continue;
        }
        visited[idx] = true;
        if let Some(key) = keys[idx] {
            if lo.map(|lo| key <= lo).unwrap_or(false) || hi.map(|hi| key >= hi).unwrap_or(false) {
                out.push(Finding::new(
                    EBST_KEY_ORDER,
                    format!("{path}.nodes[{idx}]"),
                    format!(
                        "key {key} violates BST bounds ({} .. {})",
                        lo.map(|v| v.to_string()).unwrap_or_else(|| "-inf".into()),
                        hi.map(|v| v.to_string()).unwrap_or_else(|| "inf".into()),
                    ),
                ));
            }
            if let Some((left, right)) = children[idx] {
                if left != EBST_NONE {
                    stack.push((left as usize, lo, Some(key)));
                }
                if right != EBST_NONE {
                    stack.push((right as usize, Some(key), hi));
                }
            }
        }
    }
    for (idx, seen) in visited.iter().enumerate() {
        if !seen {
            out.push(Finding::new(
                ARENA_ORPHAN,
                format!("{path}.nodes[{idx}]"),
                "node is unreachable from the root",
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// Forest payloads
// ---------------------------------------------------------------------------

fn verify_rng(j: Option<&Json>, path: &str, rule: &'static str, out: &mut Vec<Finding>) {
    let Some(j) = j else {
        out.push(Finding::new(rule, path, "missing rng state"));
        return;
    };
    match j.get("s").and_then(Json::as_arr) {
        Some(words) if words.len() == 4 && words.iter().all(|w| unum(w).is_some()) => {}
        _ => out.push(Finding::new(rule, sub(path, "s"), "expected 4 u64 xoshiro words")),
    }
    match j.get("spare") {
        Some(Json::Null) => {}
        Some(v) if fnum(v).is_some() => {}
        _ => out.push(Finding::new(rule, sub(path, "spare"), "expected null or a number")),
    }
}

fn verify_adwin(j: Option<&Json>, path: &str, out: &mut Vec<Finding>) {
    let Some(j) = j else {
        out.push(Finding::new(FOREST_SCHEMA, path, "missing detector state"));
        return;
    };
    match j.get("delta").and_then(fnum) {
        Some(d) if d > 0.0 && d < 1.0 => {}
        Some(d) => out.push(Finding::new(
            FOREST_SCHEMA,
            sub(path, "delta"),
            format!("adwin delta {d} out of (0, 1)"),
        )),
        None => out.push(Finding::new(FOREST_SCHEMA, sub(path, "delta"), "missing delta")),
    }
    match j.get("rows").and_then(Json::as_arr) {
        Some(rows) => {
            for (r, row) in rows.iter().enumerate() {
                match row.as_arr() {
                    Some(buckets) => {
                        for (b, bucket) in buckets.iter().enumerate() {
                            verify_varstats(
                                Some(bucket),
                                &format!("{path}.rows[{r}][{b}]"),
                                out,
                            );
                        }
                    }
                    None => out.push(Finding::new(
                        FOREST_SCHEMA,
                        format!("{path}.rows[{r}]"),
                        "expected a bucket row",
                    )),
                }
            }
        }
        None => out.push(Finding::new(FOREST_SCHEMA, sub(path, "rows"), "missing bucket rows")),
    }
    verify_varstats(j.get("total"), &sub(path, "total"), out);
    for key in ["tick", "n_detections"] {
        if j.get(key).and_then(unum).is_none() {
            out.push(Finding::new(FOREST_SCHEMA, sub(path, key), "missing or non-u64"));
        }
    }
    if j.get("last_shrink_rise").and_then(Json::as_bool).is_none() {
        out.push(Finding::new(FOREST_SCHEMA, sub(path, "last_shrink_rise"), "missing bool"));
    }
}

fn verify_vote(j: &Json, path: &str, out: &mut Vec<Finding>) {
    match j.get("vote_err").and_then(fnum) {
        Some(v) if v.is_finite() && v >= 0.0 => {}
        Some(v) => out.push(Finding::new(
            FOREST_SCHEMA,
            sub(path, "vote_err"),
            format!("expected a finite non-negative error EWMA, got {v}"),
        )),
        None => out.push(Finding::new(FOREST_SCHEMA, sub(path, "vote_err"), "missing")),
    }
    if j.get("vote_seeded").and_then(Json::as_bool).is_none() {
        out.push(Finding::new(FOREST_SCHEMA, sub(path, "vote_seeded"), "missing bool"));
    }
}

fn verify_arf(j: &Json, out: &mut Vec<Finding>) {
    if j.get("options").and_then(Json::as_obj).is_none() {
        out.push(Finding::new(FOREST_SCHEMA, "model.options", "missing options object"));
    }
    match j.get("observer").and_then(Json::as_str) {
        Some(label) if crate::observer::ObserverSpec::from_label(label).is_some() => {}
        Some(label) => out.push(Finding::new(
            OBSERVER_SCHEMA,
            "model.observer",
            format!("unknown observer label {label:?}"),
        )),
        None => out.push(Finding::new(FOREST_SCHEMA, "model.observer", "missing observer label")),
    }
    let n_features = j.get("n_features").and_then(unum);
    if n_features.is_none() {
        out.push(Finding::new(FOREST_SCHEMA, "model.n_features", "missing or non-u64"));
    }
    let Some(members) = j.get("members").and_then(Json::as_arr) else {
        out.push(Finding::new(FOREST_SCHEMA, "model.members", "missing member list"));
        return;
    };
    if members.is_empty() {
        out.push(Finding::new(FOREST_SCHEMA, "model.members", "forest has no members"));
    }
    for (i, m) in members.iter().enumerate() {
        let member = format!("model.members[{i}]");
        match m.get("tree") {
            Some(tree) => {
                verify_tree(tree, &sub(&member, "tree"), out);
                if let (Some(nf), Some(tf)) = (n_features, tree.get("n_features").and_then(unum)) {
                    if nf != tf {
                        out.push(Finding::new(
                            FOREST_SCHEMA,
                            sub(&member, "tree.n_features"),
                            format!("member tree has {tf} features, forest has {nf}"),
                        ));
                    }
                }
            }
            None => out.push(Finding::new(FOREST_SCHEMA, sub(&member, "tree"), "missing tree")),
        }
        match m.get("background") {
            Some(Json::Null) => {}
            Some(bg) => verify_tree(bg, &sub(&member, "background"), out),
            None => out.push(Finding::new(
                FOREST_SCHEMA,
                sub(&member, "background"),
                "missing background slot",
            )),
        }
        verify_adwin(m.get("warning"), &sub(&member, "warning"), out);
        verify_adwin(m.get("drift"), &sub(&member, "drift"), out);
        verify_rng(m.get("rng"), &sub(&member, "rng"), FOREST_SCHEMA, out);
        for key in ["fg_trained", "bg_trained"] {
            if m.get(key).and_then(Json::as_bool).is_none() {
                out.push(Finding::new(FOREST_SCHEMA, sub(&member, key), "missing bool"));
            }
        }
        for key in ["n_warnings", "n_drifts"] {
            if m.get(key).and_then(unum).is_none() {
                out.push(Finding::new(FOREST_SCHEMA, sub(&member, key), "missing or non-u64"));
            }
        }
        verify_vote(m, &member, out);
    }
}

fn verify_bagging(j: &Json, out: &mut Vec<Finding>) {
    match j.get("observer").and_then(Json::as_str) {
        Some(label) if crate::observer::ObserverSpec::from_label(label).is_some() => {}
        Some(label) => out.push(Finding::new(
            OBSERVER_SCHEMA,
            "model.observer",
            format!("unknown observer label {label:?}"),
        )),
        None => out.push(Finding::new(FOREST_SCHEMA, "model.observer", "missing observer label")),
    }
    match j.get("lambda").and_then(fnum) {
        Some(l) if l.is_finite() && l > 0.0 => {}
        Some(l) => out.push(Finding::new(
            FOREST_SCHEMA,
            "model.lambda",
            format!("Poisson lambda must be finite and > 0, got {l}"),
        )),
        None => out.push(Finding::new(FOREST_SCHEMA, "model.lambda", "missing lambda")),
    }
    if j.get("weighted_vote").and_then(Json::as_bool).is_none() {
        out.push(Finding::new(FOREST_SCHEMA, "model.weighted_vote", "missing bool"));
    }
    let Some(members) = j.get("members").and_then(Json::as_arr) else {
        out.push(Finding::new(FOREST_SCHEMA, "model.members", "missing member list"));
        return;
    };
    if members.is_empty() {
        out.push(Finding::new(FOREST_SCHEMA, "model.members", "ensemble has no members"));
    }
    for (i, m) in members.iter().enumerate() {
        let member = format!("model.members[{i}]");
        match m.get("tree") {
            Some(tree) => verify_tree(tree, &sub(&member, "tree"), out),
            None => out.push(Finding::new(FOREST_SCHEMA, sub(&member, "tree"), "missing tree")),
        }
        verify_rng(m.get("rng"), &sub(&member, "rng"), FOREST_SCHEMA, out);
        if m.get("trained").and_then(Json::as_bool).is_none() {
            out.push(Finding::new(FOREST_SCHEMA, sub(&member, "trained"), "missing bool"));
        }
        verify_vote(m, &member, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Regressor;
    use crate::observer::{factory, QuantizationObserver, RadiusPolicy};
    use crate::persist::delta::{diff, DeltaLog};
    use crate::stream::{Friedman1, Stream};
    use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

    fn trained_model(n: usize) -> Model {
        let factory = factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        });
        let mut tree = HoeffdingTreeRegressor::new(10, HtrOptions::default(), factory);
        let mut stream = Friedman1::new(3, 1.0);
        for _ in 0..n {
            let inst = stream.next_instance().unwrap();
            tree.learn_one(&inst.x, inst.y);
        }
        Model::Tree(tree)
    }

    #[test]
    fn clean_checkpoint_has_no_findings() {
        let model = trained_model(3000);
        let doc = model.to_checkpoint().unwrap();
        let findings = verify_checkpoint(&doc);
        assert!(findings.is_empty(), "false positives: {findings:?}");
        assert!(explain(&doc).is_none());
        let findings = verify_model(&model);
        assert!(findings.is_empty(), "false positives: {findings:?}");
    }

    #[test]
    fn envelope_corruption_is_flagged() {
        let model = trained_model(200);
        let mut doc = model.to_checkpoint().unwrap();
        doc.set("kind", "mystery");
        assert!(verify_checkpoint(&doc).iter().any(|f| f.rule == CKPT_ENVELOPE));
        let findings = verify_checkpoint(&Json::parse("[1,2]").unwrap());
        assert_eq!(findings[0].rule, CKPT_ENVELOPE);
    }

    #[test]
    fn swapped_arena_children_are_flagged() {
        let model = trained_model(4000);
        let mut doc = model.to_checkpoint().unwrap();
        // find a split node and point a child backwards
        let Json::Obj(root) = &mut doc else { panic!() };
        let Some(Json::Obj(m)) = root.get_mut("model") else { panic!() };
        let Some(Json::Arr(nodes)) = m.get_mut("nodes") else { panic!() };
        let mut corrupted = false;
        for node in nodes.iter_mut() {
            if let Some(mut split) = node.get("split").cloned() {
                split.set("left", crate::persist::codec::jusize(0));
                node.set("split", split);
                corrupted = true;
                break;
            }
        }
        assert!(corrupted, "trained tree should have at least one split");
        assert!(verify_checkpoint(&doc).iter().any(|f| f.rule == ARENA_CHILD_ORDER));
    }

    #[test]
    fn delta_chain_hash_and_version_rules() {
        let mut model = trained_model(1500);
        let base = model.to_checkpoint().unwrap();
        let mut stream = Friedman1::new(11, 1.0);
        let mut deltas = Vec::new();
        let mut prev = base.clone();
        for v in 0..3u64 {
            for _ in 0..400 {
                let inst = stream.next_instance().unwrap();
                model.learn_one(&inst.x, inst.y);
            }
            let next = model.to_checkpoint().unwrap();
            let mut wire = Json::obj();
            wire.set("from", crate::persist::codec::ju64(v))
                .set("to", crate::persist::codec::ju64(v + 1))
                .set("hash", crate::persist::codec::ju64(doc_hash(&next)))
                .set("ops", diff(&prev, &next));
            deltas.push(wire);
            prev = next;
        }
        assert!(verify_delta_chain(&base, &deltas).is_empty());

        let mut broken = deltas.clone();
        broken[1].set("hash", crate::persist::codec::ju64(12345));
        assert!(verify_delta_chain(&base, &broken)
            .iter()
            .any(|f| f.rule == DELTA_HASH_CHAIN));

        let mut gapped = deltas.clone();
        gapped.remove(1);
        assert!(verify_delta_chain(&base, &gapped)
            .iter()
            .any(|f| f.rule == DELTA_VERSION_ORDER));
    }

    #[test]
    fn binary_envelope_verification_matches_the_rule_catalog() {
        use crate::persist::binary::{encode_doc, HEADER_LEN, TRAILER_LEN};

        let model = trained_model(1200);
        let doc = model.to_checkpoint().unwrap();
        let bytes = encode_doc(&doc);
        let findings = verify_binary(&bytes);
        assert!(findings.is_empty(), "false positives: {findings:?}");

        // payload bit-rot: the trailer hash no longer matches
        let mut bad = bytes.clone();
        bad[HEADER_LEN + 3] ^= 0x01;
        assert!(verify_binary(&bad).iter().any(|f| f.rule == BIN_TRAILER), "{:?}", verify_binary(&bad));

        // header doc_hash no longer equals the canonical-JSON hash
        let mut bad = bytes.clone();
        bad[9] ^= 0x01;
        let findings = verify_binary(&bad);
        assert!(
            findings.iter().any(|f| f.rule == BIN_ENVELOPE && f.path == "header.doc_hash"),
            "{findings:?}"
        );

        // trailer magic overwritten
        let mut bad = bytes.clone();
        let t = bad.len() - TRAILER_LEN;
        bad[t] ^= 0xff;
        assert!(verify_binary(&bad).iter().any(|f| f.rule == BIN_TRAILER));

        // truncation and bad magic stop at the envelope rule
        assert_eq!(verify_binary(&bytes[..HEADER_LEN - 1])[0].rule, BIN_ENVELOPE);
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert_eq!(verify_binary(&bad)[0].rule, BIN_ENVELOPE);

        // a *model* corruption inside a well-formed envelope surfaces the
        // model rule: binary audits see through to the document
        let mut corrupt = doc.clone();
        corrupt.set("kind", "mystery");
        let env = encode_doc(&corrupt);
        assert!(verify_binary(&env).iter().any(|f| f.rule == CKPT_ENVELOPE));
    }

    #[test]
    fn governed_budget_claims_are_checked() {
        let model = trained_model(1500);
        let mut doc = model.to_checkpoint().unwrap();
        assert!(verify_checkpoint(&doc).is_empty());
        // honest claim: footprint comfortably inside the budget
        crate::govern::stamp_governed(&mut doc, model.mem_bytes() * 2, model.mem_bytes());
        let findings = verify_checkpoint(&doc);
        assert!(findings.is_empty(), "honest claim flagged: {findings:?}");
        // over-budget claim: the file convicts itself
        crate::govern::stamp_governed(&mut doc, 1, model.mem_bytes());
        assert!(verify_checkpoint(&doc).iter().any(|f| f.rule == GOVERN_BUDGET));
        // unparseable claim: a forged stamp is a finding, not a pass
        doc.set(crate::govern::CLAIM_KEY, "not-a-number");
        assert!(verify_checkpoint(&doc).iter().any(|f| f.rule == GOVERN_BUDGET));
    }

    #[test]
    fn delta_log_shape_is_clean_on_a_live_log() {
        let mut model = trained_model(800);
        let mut log = DeltaLog::new(model.to_checkpoint().unwrap(), 8);
        let mut stream = Friedman1::new(13, 1.0);
        for _ in 0..4 {
            for _ in 0..300 {
                let inst = stream.next_instance().unwrap();
                model.learn_one(&inst.x, inst.y);
            }
            log.publish(model.to_checkpoint().unwrap());
        }
        assert!(verify_log(&log).is_empty());
    }
}
