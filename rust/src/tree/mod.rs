//! Online regression trees: a FIMT-like Hoeffding Tree Regressor with
//! pluggable attribute observers — the system the paper's AOs exist to
//! serve, and its Sec. 7 ("integrate QO into Hoeffding trees") future
//! work, implemented here as the end-to-end driver.

pub mod htr;
pub mod leaf;
pub mod options;
pub mod subspace;

pub use htr::HoeffdingTreeRegressor;
pub use leaf::LeafModelKind;
pub use options::{HtrOptions, SplitBackendKind};
pub use subspace::SubspaceSize;
