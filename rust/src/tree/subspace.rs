//! Random feature subspaces for ensemble trees (Breiman 2001, adapted to
//! the online setting by Adaptive Random Forests, Gomes et al. 2017).
//!
//! Each leaf of an ensemble member monitors only a random subset of the
//! input features; the observers for the unmonitored features are never
//! built, which both decorrelates the members (the accuracy lever) and
//! multiplies the memory savings of the Quantization Observer (the cost
//! lever). The subset is re-drawn for every new leaf, so a single tree
//! still sees every feature somewhere in its structure.
//!
//! This lives in the tree layer (it depends only on [`crate::common`])
//! so the core tree stays independent of the ensemble subsystem;
//! [`crate::forest`] re-exports it. [`SubspaceSize`] is the policy knob
//! on [`super::HtrOptions`]; [`sample_subspace`] is the draw itself.

use crate::common::Rng;

/// How many features each leaf monitors.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubspaceSize {
    /// Monitor everything (plain Hoeffding tree; the default).
    All,
    /// ⌈√d⌉ features — the random-forest convention.
    Sqrt,
    /// ⌈f·d⌉ features for a fraction `f` in (0, 1].
    Fraction(f64),
    /// Exactly `k` features (clamped to `[1, d]`).
    Fixed(usize),
}

impl Default for SubspaceSize {
    fn default() -> SubspaceSize {
        SubspaceSize::All
    }
}

impl SubspaceSize {
    /// Resolve the policy to a concrete count for `d` input features.
    pub fn resolve(&self, d: usize) -> usize {
        let k = match *self {
            SubspaceSize::All => d,
            SubspaceSize::Sqrt => (d as f64).sqrt().ceil() as usize,
            SubspaceSize::Fraction(f) => (f * d as f64).ceil() as usize,
            SubspaceSize::Fixed(k) => k,
        };
        k.clamp(1, d.max(1))
    }

    /// Parse a CLI spelling: `all`, `sqrt`, a fraction in (0, 1) or an
    /// integer count.
    pub fn parse(s: &str) -> Option<SubspaceSize> {
        match s {
            "all" => Some(SubspaceSize::All),
            "sqrt" => Some(SubspaceSize::Sqrt),
            _ => {
                if let Ok(k) = s.parse::<usize>() {
                    return Some(SubspaceSize::Fixed(k));
                }
                match s.parse::<f64>() {
                    Ok(f) if f > 0.0 && f < 1.0 => Some(SubspaceSize::Fraction(f)),
                    _ => None,
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            SubspaceSize::All => "all".to_string(),
            SubspaceSize::Sqrt => "sqrt".to_string(),
            SubspaceSize::Fraction(f) => format!("{f}"),
            SubspaceSize::Fixed(k) => format!("{k}"),
        }
    }
}

/// Draw `k` distinct feature indices out of `0..d`, sorted ascending
/// (partial Fisher–Yates; O(d) per draw). `k >= d` returns the full range
/// without consuming randomness, so `SubspaceSize::All` trees stay
/// bit-identical to pre-subspace builds.
pub fn sample_subspace(rng: &mut Rng, d: usize, k: usize) -> Vec<usize> {
    if k >= d {
        return (0..d).collect();
    }
    let mut idx: Vec<usize> = (0..d).collect();
    for i in 0..k {
        let j = i + rng.below((d - i) as u64) as usize;
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::check;

    #[test]
    fn resolve_covers_policies() {
        assert_eq!(SubspaceSize::All.resolve(10), 10);
        assert_eq!(SubspaceSize::Sqrt.resolve(10), 4);
        assert_eq!(SubspaceSize::Sqrt.resolve(9), 3);
        assert_eq!(SubspaceSize::Fraction(0.6).resolve(10), 6);
        assert_eq!(SubspaceSize::Fixed(3).resolve(10), 3);
        assert_eq!(SubspaceSize::Fixed(99).resolve(10), 10);
        assert_eq!(SubspaceSize::Fixed(0).resolve(10), 1);
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(SubspaceSize::parse("all"), Some(SubspaceSize::All));
        assert_eq!(SubspaceSize::parse("sqrt"), Some(SubspaceSize::Sqrt));
        assert_eq!(SubspaceSize::parse("4"), Some(SubspaceSize::Fixed(4)));
        assert_eq!(SubspaceSize::parse("0.5"), Some(SubspaceSize::Fraction(0.5)));
        assert_eq!(SubspaceSize::parse("nope"), None);
        assert_eq!(SubspaceSize::parse("1.5"), None);
    }

    #[test]
    fn full_draw_consumes_no_randomness() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        assert_eq!(sample_subspace(&mut a, 5, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn prop_subspace_is_sorted_distinct_in_range() {
        check("subspace-valid", 0xE0, 100, |rng| {
            let d = 1 + rng.below(20) as usize;
            let k = 1 + rng.below(d as u64) as usize;
            let s = sample_subspace(rng, d, k);
            if s.len() != k {
                return Err(format!("len {} != k {k}", s.len()));
            }
            for w in s.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("not sorted/distinct: {s:?}"));
                }
            }
            if s.iter().any(|&f| f >= d) {
                return Err(format!("out of range: {s:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn draws_cover_all_features_eventually() {
        let mut rng = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..200 {
            for f in sample_subspace(&mut rng, 10, 3) {
                seen[f] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }
}
