//! Leaf state of the Hoeffding Tree Regressor: per-feature attribute
//! observers, target statistics and the leaf prediction model.

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::eval::baselines::LinearSgd;
use crate::eval::Regressor;
use crate::observer::{observer_from_json, AttributeObserver, ObserverFactory};
use crate::persist::codec::{
    field, jf64, jusize, parr, pf64, pstr, pusize, varstats_from, varstats_to_json,
};
use crate::stats::VarStats;

/// Leaf prediction strategy (FIMT: target mean / perceptron / adaptive).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeafModelKind {
    /// Predict the leaf's target mean.
    Mean,
    /// Predict with the leaf's linear SGD model.
    Linear,
    /// Track faded errors of both and predict with whichever is currently
    /// more accurate (FIMT-DD's adaptive node model).
    Adaptive,
}

impl LeafModelKind {
    /// Stable spelling used by the CLI and the checkpoint codec.
    pub fn label(&self) -> &'static str {
        match self {
            LeafModelKind::Mean => "mean",
            LeafModelKind::Linear => "linear",
            LeafModelKind::Adaptive => "adaptive",
        }
    }

    /// Parse a [`LeafModelKind::label`] spelling.
    pub fn parse(s: &str) -> Option<LeafModelKind> {
        match s {
            "mean" => Some(LeafModelKind::Mean),
            "linear" => Some(LeafModelKind::Linear),
            "adaptive" => Some(LeafModelKind::Adaptive),
            _ => None,
        }
    }
}

/// Fading factor for the adaptive model's error trackers.
const FADE: f64 = 0.995;

/// Mutable state of one leaf.
///
/// `Clone` (through [`AttributeObserver::clone_box`]) is what powers the
/// copy-on-write snapshot path: published snapshots share leaves behind
/// `Arc`, and the trainer deep-clones only the leaves it touches
/// afterwards ([`crate::tree::HoeffdingTreeRegressor`]).
#[derive(Clone)]
pub struct LeafState {
    /// Robust statistics of the leaf's target distribution. May be
    /// warm-started from the parent branch statistics at split time.
    pub stats: VarStats,
    /// One observer per *monitored* feature (None when deactivated at max
    /// depth — the leaf then stops paying observation costs).
    pub observers: Option<Vec<Box<dyn AttributeObserver>>>,
    /// Feature index each observer watches: `observers[i]` monitors
    /// `x[monitored[i]]`. The full range for a plain tree; a random
    /// subspace for ensemble members (see [`super::subspace`]).
    pub monitored: Vec<usize>,
    pub linear: LinearSgd,
    pub kind: LeafModelKind,
    /// Faded absolute error of the mean / linear predictors (Adaptive).
    pub mean_err: f64,
    pub lin_err: f64,
    /// Weight observed since the last split attempt.
    pub weight_since_attempt: f64,
    pub depth: usize,
}

impl LeafState {
    pub fn new(
        n_features: usize,
        monitored: Vec<usize>,
        factory: &dyn ObserverFactory,
        kind: LeafModelKind,
        lr: f64,
        depth: usize,
        active: bool,
    ) -> LeafState {
        debug_assert!(monitored.iter().all(|&f| f < n_features));
        LeafState {
            stats: VarStats::new(),
            observers: active.then(|| monitored.iter().map(|_| factory.build()).collect()),
            monitored,
            linear: LinearSgd::new(n_features, lr),
            kind,
            mean_err: 0.0,
            lin_err: 0.0,
            weight_since_attempt: 0.0,
            depth,
        }
    }

    pub fn is_active(&self) -> bool {
        self.observers.is_some()
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        match self.kind {
            LeafModelKind::Mean => self.stats.mean,
            LeafModelKind::Linear => self.linear.predict(x),
            LeafModelKind::Adaptive => {
                if self.lin_err <= self.mean_err {
                    self.linear.predict(x)
                } else {
                    self.stats.mean
                }
            }
        }
    }

    pub fn learn(&mut self, x: &[f64], y: f64, w: f64) {
        if self.kind == LeafModelKind::Adaptive {
            self.mean_err = FADE * self.mean_err + (y - self.stats.mean).abs();
        }
        self.stats.update(y, w);
        // fused: one normalized pass does both the error tracking and the
        // gradient step (perf: avoids a second predict_norm loop)
        let lin_pred = self.linear.learn_returning_prediction(x, y);
        if self.kind == LeafModelKind::Adaptive {
            self.lin_err = FADE * self.lin_err + (y - lin_pred).abs();
        }
        if let Some(observers) = &mut self.observers {
            for (ao, &f) in observers.iter_mut().zip(&self.monitored) {
                ao.observe(x[f], y, w);
            }
        }
        self.weight_since_attempt += w;
    }

    /// Checkpoint encoding ([`crate::persist`]): everything the leaf owns,
    /// including the full state of each observer. Returns an error when an
    /// observer kind does not support serialization (a custom
    /// [`AttributeObserver`] that kept the default `to_json`).
    pub fn to_json(&self) -> Result<Json> {
        let observers = match &self.observers {
            None => Json::Null,
            Some(obs) => {
                let mut items = Vec::with_capacity(obs.len());
                for ao in obs {
                    let encoded = ao.to_json();
                    if encoded.is_null() {
                        return Err(anyhow!(
                            "observer {:?} does not support checkpointing",
                            ao.name()
                        ));
                    }
                    items.push(encoded);
                }
                Json::Arr(items)
            }
        };
        let mut o = Json::obj();
        o.set("stats", varstats_to_json(&self.stats))
            .set("observers", observers)
            .set(
                "monitored",
                Json::Arr(self.monitored.iter().map(|&f| jusize(f)).collect()),
            )
            .set("linear", self.linear.to_json())
            .set("kind", self.kind.label())
            .set("mean_err", jf64(self.mean_err))
            .set("lin_err", jf64(self.lin_err))
            .set("weight_since_attempt", jf64(self.weight_since_attempt))
            .set("depth", jusize(self.depth));
        Ok(o)
    }

    /// Decode a leaf written by [`LeafState::to_json`].
    pub fn from_json(j: &Json) -> Result<LeafState> {
        let observers = match field(j, "observers")? {
            Json::Null => None,
            arr => {
                let mut obs: Vec<Box<dyn AttributeObserver>> = Vec::new();
                for item in parr(arr, "observers")? {
                    obs.push(observer_from_json(item)?);
                }
                Some(obs)
            }
        };
        let monitored: Vec<usize> = parr(field(j, "monitored")?, "monitored")?
            .iter()
            .map(|f| pusize(f, "monitored"))
            .collect::<Result<_>>()?;
        if let Some(obs) = &observers {
            if obs.len() != monitored.len() {
                return Err(anyhow!(
                    "leaf: {} observers for {} monitored features",
                    obs.len(),
                    monitored.len()
                ));
            }
        }
        let kind_label = pstr(field(j, "kind")?, "kind")?;
        Ok(LeafState {
            stats: varstats_from(field(j, "stats")?, "stats")?,
            observers,
            monitored,
            linear: LinearSgd::from_json(field(j, "linear")?)?,
            kind: LeafModelKind::parse(kind_label)
                .ok_or_else(|| anyhow!("unknown leaf model {kind_label:?}"))?,
            mean_err: pf64(field(j, "mean_err")?, "mean_err")?,
            lin_err: pf64(field(j, "lin_err")?, "lin_err")?,
            weight_since_attempt: pf64(
                field(j, "weight_since_attempt")?,
                "weight_since_attempt",
            )?,
            depth: pusize(field(j, "depth")?, "depth")?,
        })
    }

    /// Resident heap footprint in bytes: the leaf struct, each observer's
    /// allocations, the monitored-feature list and the linear model. Used
    /// by [`crate::obs`]'s `model_mem_bytes` gauge (the byte-level
    /// companion of [`LeafState::n_elements`]).
    pub fn mem_bytes(&self) -> usize {
        let observers = self
            .observers
            .as_ref()
            .map(|obs| {
                obs.iter()
                    .map(|o| std::mem::size_of::<Box<dyn AttributeObserver>>() + o.mem_bytes())
                    .sum::<usize>()
            })
            .unwrap_or(0);
        std::mem::size_of::<LeafState>()
            + observers
            + self.monitored.capacity() * std::mem::size_of::<usize>()
            + self.linear.mem_bytes()
    }

    /// Total stored elements across this leaf's observers (the paper's
    /// memory metric).
    pub fn n_elements(&self) -> usize {
        self.observers
            .as_ref()
            .map(|obs| obs.iter().map(|o| o.n_elements()).sum())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::observer::{factory, QuantizationObserver, RadiusPolicy};

    fn qo_factory() -> Box<dyn crate::observer::ObserverFactory> {
        factory("QO", || Box::new(QuantizationObserver::new(RadiusPolicy::Fixed(0.1))))
    }

    #[test]
    fn inactive_leaf_has_no_observers() {
        let leaf = LeafState::new(
            3,
            vec![0, 1, 2],
            qo_factory().as_ref(),
            LeafModelKind::Mean,
            0.02,
            5,
            false,
        );
        assert!(!leaf.is_active());
        assert_eq!(leaf.n_elements(), 0);
    }

    #[test]
    fn learn_updates_stats_and_observers() {
        let mut leaf =
            LeafState::new(2, vec![0, 1], qo_factory().as_ref(), LeafModelKind::Mean, 0.02, 0, true);
        leaf.learn(&[0.5, -0.5], 2.0, 1.0);
        leaf.learn(&[0.7, 0.1], 4.0, 1.0);
        assert_eq!(leaf.stats.n, 2.0);
        assert!((leaf.predict(&[0.0, 0.0]) - 3.0).abs() < 1e-12);
        assert!(leaf.n_elements() >= 2);
        assert_eq!(leaf.weight_since_attempt, 2.0);
    }

    #[test]
    fn subspace_leaf_observes_only_monitored_features() {
        // monitor only feature 1: the observer must see x[1], not x[0]
        let mut leaf =
            LeafState::new(2, vec![1], qo_factory().as_ref(), LeafModelKind::Mean, 0.02, 0, true);
        for i in 0..50 {
            // x[0] wanders over many radius-0.1 buckets; x[1] stays in one
            leaf.learn(&[i as f64, 0.05], i as f64, 1.0);
        }
        let observers = leaf.observers.as_ref().unwrap();
        assert_eq!(observers.len(), 1);
        assert_eq!(observers[0].n_elements(), 1, "x[1] is constant: one slot");
        assert_eq!(leaf.stats.n, 50.0);
    }

    #[test]
    fn leaf_json_roundtrip_continues_identically() {
        let mut leaf = LeafState::new(
            2,
            vec![0, 1],
            qo_factory().as_ref(),
            LeafModelKind::Adaptive,
            0.02,
            1,
            true,
        );
        let mut rng = Rng::new(61);
        for _ in 0..300 {
            let x = [rng.f64(), rng.normal(0.0, 1.0)];
            leaf.learn(&x, 3.0 * x[0], 1.0);
        }
        let text = leaf.to_json().unwrap().to_compact();
        let mut back =
            LeafState::from_json(&crate::common::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.monitored, leaf.monitored);
        assert_eq!(back.depth, leaf.depth);
        assert_eq!(back.n_elements(), leaf.n_elements());
        let probe = [0.4, -0.2];
        assert_eq!(leaf.predict(&probe).to_bits(), back.predict(&probe).to_bits());
        for _ in 0..100 {
            let x = [rng.f64(), rng.normal(0.0, 1.0)];
            let y = 3.0 * x[0];
            leaf.learn(&x, y, 1.0);
            back.learn(&x, y, 1.0);
        }
        assert_eq!(leaf.predict(&probe).to_bits(), back.predict(&probe).to_bits());
        assert_eq!(
            leaf.weight_since_attempt.to_bits(),
            back.weight_since_attempt.to_bits()
        );
    }

    #[test]
    fn frozen_leaf_roundtrips_without_observers() {
        let leaf = LeafState::new(
            1,
            vec![0],
            qo_factory().as_ref(),
            LeafModelKind::Mean,
            0.02,
            5,
            false,
        );
        let back = LeafState::from_json(
            &crate::common::json::Json::parse(&leaf.to_json().unwrap().to_compact())
                .unwrap(),
        )
        .unwrap();
        assert!(!back.is_active());
    }

    #[test]
    fn leaf_model_kind_labels_roundtrip() {
        for kind in [LeafModelKind::Mean, LeafModelKind::Linear, LeafModelKind::Adaptive] {
            assert_eq!(LeafModelKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(LeafModelKind::parse("nope"), None);
    }

    #[test]
    fn adaptive_switches_to_linear_on_linear_data() {
        let mut leaf =
            LeafState::new(1, vec![0], qo_factory().as_ref(), LeafModelKind::Adaptive, 0.05, 0, true);
        let mut rng = Rng::new(41);
        for _ in 0..5000 {
            let x = rng.uniform(-1.0, 1.0);
            leaf.learn(&[x], 4.0 * x, 1.0);
        }
        assert!(leaf.lin_err < leaf.mean_err, "lin={} mean={}", leaf.lin_err, leaf.mean_err);
        let x = [0.5];
        assert!((leaf.predict(&x) - 2.0).abs() < 0.5);
    }
}
