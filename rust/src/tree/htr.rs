//! The Hoeffding Tree Regressor (FIMT-like; Ikonomovska et al. 2011).
//!
//! Instances are routed to a leaf, which updates its prediction model and
//! its per-feature attribute observers. Every `grace_period` observations
//! the leaf asks each observer for its best split; the tree splits when
//! the Hoeffding bound guarantees (with confidence 1 − δ) that the best
//! candidate's merit genuinely dominates the runner-up's, or when the two
//! are tied within τ.
//!
//! The observer type is pluggable ([`ObserverFactory`]) — this is where
//! the paper's QO vs E-BST trade-off plays out inside a real model.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::common::Rng;
use crate::criterion::{SdReduction, SplitCriterion, VarianceReduction};
use crate::eval::Regressor;
use crate::obs;
use crate::observer::{AttributeObserver, ObserverFactory, ObserverSpec, SplitSuggestion};
use crate::persist::codec::{
    field, jf64, jusize, parr, pf64, pstr, pusize, rng_from, rng_to_json,
};
use crate::runtime::backend::{SplitBackend, SplitQuery};

use super::subspace::sample_subspace;

use super::leaf::LeafState;
use super::options::HtrOptions;

/// Arena node. Leaves live behind `Arc` so cloning a tree (the serve
/// layer's snapshot hot-swap) shares every leaf with the clone; the
/// trainer copy-on-writes a leaf (via [`Arc::make_mut`]) only when it
/// next touches it, making the clone O(touched) deep work instead of
/// O(model).
#[derive(Clone)]
enum Node {
    Leaf(Arc<LeafState>),
    Split { feature: usize, threshold: f64, left: u32, right: u32 },
}

/// FIMT-like Hoeffding tree for streaming regression.
///
/// `Clone` is cheap-by-sharing: the node arena is copied, but every leaf
/// (the heavy state: observers, slot tables, linear models) is shared
/// behind `Arc` and only deep-copied when the original tree mutates it
/// again — see [`crate::serve`]'s zero-copy snapshots and
/// `docs/FORMATS.md`.
#[derive(Clone)]
pub struct HoeffdingTreeRegressor {
    nodes: Vec<Node>,
    root: u32,
    n_features: usize,
    options: HtrOptions,
    factory: Arc<dyn ObserverFactory>,
    criterion: Arc<dyn SplitCriterion>,
    n_splits: usize,
    observer_label: String,
    /// Subspace draws (and any future stochastic choices). With
    /// `SubspaceSize::All` it is never consumed, so plain trees remain
    /// bit-for-bit reproducible regardless of `options.seed`.
    rng: Rng,
    /// Split-query engine (`None` = the inline per-observer loop).
    backend: Option<Arc<dyn SplitBackend>>,
    /// Leaves whose split attempts became due in deferred mode
    /// ([`Self::learn_one_deferred`]), awaiting a batched flush.
    pending: Vec<u32>,
    /// Instances absorbed since [`Self::mark_synced`] — runtime-only
    /// touched-state tracking for the serve/replication layer (how stale
    /// a published snapshot is); deliberately NOT checkpointed: it
    /// describes the sync cadence, not the model.
    learns_since_sync: u64,
}

impl HoeffdingTreeRegressor {
    pub fn new(
        n_features: usize,
        options: HtrOptions,
        factory: Box<dyn ObserverFactory>,
    ) -> HoeffdingTreeRegressor {
        let observer_label = factory.name();
        let mut rng = Rng::new(options.seed);
        let k = options.subspace.resolve(n_features);
        let monitored = sample_subspace(&mut rng, n_features, k);
        let root_leaf = Node::Leaf(Arc::new(LeafState::new(
            n_features,
            monitored,
            factory.as_ref(),
            options.leaf_model,
            options.leaf_lr,
            0,
            options.max_depth > 0,
        )));
        let backend = options.split_backend.instantiate();
        HoeffdingTreeRegressor {
            nodes: vec![root_leaf],
            root: 0,
            n_features,
            options,
            factory: Arc::from(factory),
            criterion: Arc::new(VarianceReduction),
            n_splits: 0,
            observer_label,
            rng,
            backend,
            pending: Vec::new(),
            learns_since_sync: 0,
        }
    }

    /// Replace the split criterion (default: Variance Reduction).
    pub fn with_criterion(mut self, criterion: Box<dyn SplitCriterion>) -> Self {
        self.criterion = Arc::from(criterion);
        self
    }

    /// Replace the split-query backend (e.g. an externally loaded
    /// [`crate::runtime::backend::XlaSplitBackend`]), overriding whatever
    /// [`HtrOptions::split_backend`] instantiated.
    pub fn with_split_backend(mut self, backend: Arc<dyn SplitBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// The criterion split candidates are scored under.
    pub fn criterion(&self) -> &dyn SplitCriterion {
        self.criterion.as_ref()
    }

    /// The tree's configuration.
    pub fn options(&self) -> &HtrOptions {
        &self.options
    }

    /// Input dimensionality the tree was built for.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    fn route(&self, x: &[f64]) -> u32 {
        let mut idx = self.root;
        loop {
            match &self.nodes[idx as usize] {
                Node::Leaf(_) => return idx,
                Node::Split { feature, threshold, left, right } => {
                    idx = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Split decision per the Hoeffding bound over merit ratios,
    /// classified by outcome (`split()` says whether to materialize; the
    /// full verdict feeds the [`obs`] split-decision trace).
    fn split_verdict(
        &self,
        best: &SplitSuggestion,
        second_merit: f64,
        n: f64,
    ) -> obs::SplitOutcome {
        use obs::SplitOutcome as O;
        if best.merit <= 0.0 {
            return O::NoMerit;
        }
        // reject degenerate partitions
        let total_n = best.left.n + best.right.n;
        let min_branch = self.options.min_branch_frac * total_n;
        if best.left.n < min_branch || best.right.n < min_branch {
            return O::BranchTooSmall;
        }
        let eps = self.options.hoeffding_bound(n);
        if second_merit <= 0.0 {
            // single (or uniquely positive) candidate: require the bound
            // to have tightened enough that ties would be declared
            return if eps < self.options.tie_threshold {
                O::TieBroken
            } else {
                O::HoeffdingRejected
            };
        }
        let ratio = second_merit / best.merit;
        if ratio < 1.0 - eps {
            O::Accepted
        } else if eps < self.options.tie_threshold {
            O::TieBroken
        } else {
            O::HoeffdingRejected
        }
    }

    /// Evaluate a due leaf's candidates — through the configured backend
    /// when one is set, else the inline per-observer loop — and split if
    /// the Hoeffding bound allows.
    fn attempt_split(&mut self, leaf_idx: u32) {
        if let Some(backend) = self.backend.clone() {
            return self.attempt_split_through(leaf_idx, backend.as_ref());
        }
        let suggestions: Vec<Option<SplitSuggestion>> = {
            let Node::Leaf(leaf) = &self.nodes[leaf_idx as usize] else { return };
            let Some(observers) = &leaf.observers else { return };
            observers
                .iter()
                .map(|ao| ao.best_split(self.criterion.as_ref()))
                .collect()
        };
        self.resolve_attempt(leaf_idx, &suggestions);
    }

    /// Evaluate one leaf's candidates through an explicit backend (the
    /// configured one, or a flush-supplied one in deferred mode — see
    /// [`Self::learn_one_deferred`]).
    fn attempt_split_through(&mut self, leaf_idx: u32, backend: &dyn SplitBackend) {
        let suggestions = {
            let Node::Leaf(leaf) = &self.nodes[leaf_idx as usize] else { return };
            let Some(observers) = &leaf.observers else { return };
            let queries: Vec<SplitQuery<'_>> = observers
                .iter()
                .map(|ao| SplitQuery {
                    observer: ao.as_ref(),
                    criterion: self.criterion.as_ref(),
                })
                .collect();
            let started = obs::m().map(|_| std::time::Instant::now());
            let results = backend.best_splits(&queries);
            if let Some(m) = obs::m() {
                m.backend_batches.inc();
                m.backend_batch_size.record(queries.len() as u64);
                if let Some(t) = started {
                    m.backend_latency_ns.record(t.elapsed().as_nanos() as u64);
                }
            }
            results
        };
        self.resolve_attempt(leaf_idx, &suggestions);
    }

    /// Apply externally evaluated split-candidate results to a leaf:
    /// `suggestions[i]` answers observer slot `i` (as returned by a
    /// [`SplitBackend`] over [`Self::leaf_observers`]). Selects the best
    /// and runner-up candidates exactly like the inline loop, then splits
    /// if the Hoeffding bound allows. No-op when the node is no longer an
    /// active leaf.
    pub fn resolve_attempt(&mut self, leaf_idx: u32, suggestions: &[Option<SplitSuggestion>]) {
        let started = obs::m().map(|_| std::time::Instant::now());
        let (best, second_merit, n, depth, slots_evaluated) = {
            let Node::Leaf(leaf) = &self.nodes[leaf_idx as usize] else { return };
            if !leaf.is_active() {
                return;
            }
            debug_assert_eq!(suggestions.len(), leaf.monitored.len());
            let mut best: Option<(usize, SplitSuggestion)> = None;
            let mut second = 0.0f64;
            for (slot, suggestion) in suggestions.iter().enumerate() {
                if let Some(s) = suggestion {
                    match &best {
                        Some((_, b)) if s.merit <= b.merit => second = second.max(s.merit),
                        _ => {
                            if let Some((_, b)) = &best {
                                second = second.max(b.merit);
                            }
                            // observers are indexed by slot; the split acts
                            // on the slot's monitored feature
                            best = Some((leaf.monitored[slot], *s));
                        }
                    }
                }
            }
            let Some((feature, suggestion)) = best else { return };
            (
                (feature, suggestion),
                second,
                leaf.stats.n,
                leaf.depth,
                leaf.n_elements() as u64,
            )
        };
        let (feature, suggestion) = best;
        let verdict = self.split_verdict(&suggestion, second_merit, n);
        if let Some(m) = obs::m() {
            m.count_split_outcome(verdict);
            m.split_trace.record(obs::SplitEvent {
                outcome: verdict,
                merit_gap: suggestion.merit - second_merit,
                slots_evaluated,
                elapsed_ns: started.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0),
            });
        }
        if !verdict.split() {
            return;
        }

        // materialize the split: two fresh leaves, target stats warm-
        // started from the winning partition (FIMT), fresh observers over
        // freshly drawn feature subspaces, the parent's linear model
        // cloned into both children.
        let child_active = depth + 1 < self.options.max_depth;
        let parent_linear = {
            let Node::Leaf(leaf) = &self.nodes[leaf_idx as usize] else { unreachable!() };
            leaf.linear.clone()
        };
        let k = self.options.subspace.resolve(self.n_features);
        let monitored_left = sample_subspace(&mut self.rng, self.n_features, k);
        let monitored_right = sample_subspace(&mut self.rng, self.n_features, k);
        let mut mk_child = |monitored: Vec<usize>, stats: crate::stats::VarStats| -> u32 {
            let mut child = LeafState::new(
                self.n_features,
                monitored,
                self.factory.as_ref(),
                self.options.leaf_model,
                self.options.leaf_lr,
                depth + 1,
                child_active,
            );
            child.stats = stats;
            child.linear = parent_linear.clone();
            self.nodes.push(Node::Leaf(Arc::new(child)));
            (self.nodes.len() - 1) as u32
        };
        let left = mk_child(monitored_left, suggestion.left);
        let right = mk_child(monitored_right, suggestion.right);
        self.nodes[leaf_idx as usize] =
            Node::Split { feature, threshold: suggestion.threshold, left, right };
        self.n_splits += 1;
    }

    /// Route + learn one instance; returns the leaf when a split attempt
    /// became due (shared by the inline and deferred learn paths).
    fn learn_routing(&mut self, x: &[f64], y: f64) -> Option<u32> {
        debug_assert_eq!(x.len(), self.n_features);
        self.learns_since_sync += 1;
        let leaf_idx = self.route(x);
        let Node::Leaf(leaf) = &mut self.nodes[leaf_idx as usize] else { unreachable!() };
        // copy-on-write: if a published snapshot still shares this leaf,
        // deep-clone it now (once per leaf per publish) and mutate the
        // private copy; unshared leaves mutate in place at zero cost
        let leaf = Arc::make_mut(leaf);
        leaf.learn(x, y, 1.0);
        if let Some(m) = obs::m() {
            m.tree_learns.inc();
            m.tree_route_depth.record(leaf.depth as u64);
        }
        if leaf.weight_since_attempt >= self.options.grace_period as f64 {
            leaf.weight_since_attempt = 0.0;
            Some(leaf_idx)
        } else {
            None
        }
    }

    /// Deferred-attempt mode: like [`Regressor::learn_one`], but a due
    /// split attempt is queued on the tree instead of evaluated inline.
    /// Ensembles use this to collect every member's due leaves and flush
    /// them through one batched backend call per round
    /// ([`crate::forest::batch::flush_split_attempts`]); a single tree can
    /// flush its own queue with [`Self::flush_pending`].
    pub fn learn_one_deferred(&mut self, x: &[f64], y: f64) {
        if let Some(leaf_idx) = self.learn_routing(x, y) {
            if !self.pending.contains(&leaf_idx) {
                self.pending.push(leaf_idx);
            }
        }
    }

    /// Leaves queued by [`Self::learn_one_deferred`], not yet flushed.
    pub fn pending_attempts(&self) -> &[u32] {
        &self.pending
    }

    /// Drain the deferred-attempt queue (callers evaluate the returned
    /// leaves via [`Self::leaf_observers`] + [`Self::resolve_attempt`]).
    pub fn take_pending(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.pending)
    }

    /// Observer handles of a leaf, in slot order (empty when the node is
    /// frozen or no longer a leaf).
    pub fn leaf_observers(&self, leaf_idx: u32) -> &[Box<dyn AttributeObserver>] {
        match &self.nodes[leaf_idx as usize] {
            Node::Leaf(leaf) => leaf.observers.as_deref().unwrap_or(&[]),
            _ => &[],
        }
    }

    /// Evaluate and resolve every queued attempt through `backend` (each
    /// leaf's features still batch into one backend call).
    pub fn flush_pending(&mut self, backend: &dyn SplitBackend) {
        for leaf_idx in self.take_pending() {
            self.attempt_split_through(leaf_idx, backend);
        }
    }

    /// Instances absorbed since the last [`Self::mark_synced`] (covers
    /// both the inline and deferred learn paths). The serve layer's
    /// publisher uses a zero here to skip the encode → decode → diff
    /// round-trip when an explicit snapshot arrives with nothing new.
    pub fn learns_since_sync(&self) -> u64 {
        self.learns_since_sync
    }

    /// Reset the touched-state counter (called when a snapshot/delta of
    /// this tree has been published).
    pub fn mark_synced(&mut self) {
        self.learns_since_sync = 0;
    }

    pub fn n_splits(&self) -> usize {
        self.n_splits
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Leaf(_))).count()
    }

    pub fn depth(&self) -> usize {
        self.depth_of(self.root)
    }

    fn depth_of(&self, idx: u32) -> usize {
        match &self.nodes[idx as usize] {
            Node::Leaf(_) => 0,
            Node::Split { left, right, .. } => {
                1 + self.depth_of(*left).max(self.depth_of(*right))
            }
        }
    }

    /// Pretty-print the structure (for the examples / debugging).
    pub fn describe(&self) -> String {
        let mut out = String::new();
        self.describe_node(self.root, 0, &mut out);
        out
    }

    fn describe_node(&self, idx: u32, indent: usize, out: &mut String) {
        let pad = "  ".repeat(indent);
        match &self.nodes[idx as usize] {
            Node::Leaf(leaf) => {
                out.push_str(&format!(
                    "{pad}leaf(n={:.0}, mean={:.4}{})\n",
                    leaf.stats.n,
                    leaf.stats.mean,
                    if leaf.is_active() { "" } else { ", frozen" }
                ));
            }
            Node::Split { feature, threshold, left, right } => {
                out.push_str(&format!("{pad}if x[{feature}] <= {threshold:.5}:\n"));
                self.describe_node(*left, indent + 1, out);
                out.push_str(&format!("{pad}else:\n"));
                self.describe_node(*right, indent + 1, out);
            }
        }
    }

    /// Checkpoint encoding ([`crate::persist`]): the full arena (leaves
    /// with their observers and models, split nodes), options, PRNG state
    /// and the deferred-attempt queue — everything needed for
    /// `save → load` to be bit-for-bit invisible to both prediction and
    /// continued training. Fails when the observer factory's label is not
    /// [`ObserverSpec`]-representable (a custom closure factory) or an
    /// observer kind does not serialize.
    pub fn to_json(&self) -> Result<Json> {
        let spec = ObserverSpec::from_label(&self.observer_label).ok_or_else(|| {
            anyhow!(
                "observer factory {:?} is not checkpointable (no ObserverSpec label)",
                self.observer_label
            )
        })?;
        let mut nodes = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let mut o = Json::obj();
            match node {
                Node::Leaf(leaf) => {
                    o.set("leaf", leaf.to_json()?);
                }
                Node::Split { feature, threshold, left, right } => {
                    let mut s = Json::obj();
                    s.set("feature", jusize(*feature))
                        .set("threshold", jf64(*threshold))
                        .set("left", jusize(*left as usize))
                        .set("right", jusize(*right as usize));
                    o.set("split", s);
                }
            }
            nodes.push(o);
        }
        let mut o = Json::obj();
        o.set("n_features", jusize(self.n_features))
            .set("options", self.options.to_json())
            .set("observer", spec.label())
            .set("criterion", self.criterion.name())
            .set("n_splits", jusize(self.n_splits))
            .set("rng", rng_to_json(&self.rng))
            .set("root", jusize(self.root as usize))
            .set(
                "pending",
                Json::Arr(self.pending.iter().map(|&l| jusize(l as usize)).collect()),
            )
            .set("nodes", Json::Arr(nodes));
        Ok(o)
    }

    /// Decode a tree written by [`Self::to_json`]. The split backend is
    /// re-instantiated from the restored options (backend objects are
    /// stateless engines, not model state).
    pub fn from_json(j: &Json) -> Result<HoeffdingTreeRegressor> {
        let options = HtrOptions::from_json(field(j, "options")?)?;
        let label = pstr(field(j, "observer")?, "observer")?;
        let spec = ObserverSpec::from_label(label)
            .ok_or_else(|| anyhow!("unknown observer label {label:?}"))?;
        let criterion: Box<dyn SplitCriterion> =
            match pstr(field(j, "criterion")?, "criterion")? {
                "variance-reduction" => Box::new(VarianceReduction),
                "sd-reduction" => Box::new(SdReduction),
                other => return Err(anyhow!("unknown split criterion {other:?}")),
            };
        let n_features = pusize(field(j, "n_features")?, "n_features")?;
        let raw = parr(field(j, "nodes")?, "nodes")?;
        if raw.is_empty() {
            return Err(anyhow!("tree checkpoint has no nodes"));
        }
        let mut nodes = Vec::with_capacity(raw.len());
        for (idx, item) in raw.iter().enumerate() {
            if let Some(leaf) = item.get("leaf") {
                let leaf = LeafState::from_json(leaf)?;
                if leaf.monitored.iter().any(|&f| f >= n_features) {
                    return Err(anyhow!("leaf monitors a feature out of range"));
                }
                if leaf.linear.n_elements() != n_features + 1 {
                    return Err(anyhow!("leaf linear model dimensionality mismatch"));
                }
                nodes.push(Node::Leaf(Arc::new(leaf)));
            } else if let Some(split) = item.get("split") {
                let left = pusize(field(split, "left")?, "left")?;
                let right = pusize(field(split, "right")?, "right")?;
                if left >= raw.len() || right >= raw.len() {
                    return Err(anyhow!("split child index out of range"));
                }
                // live trees only ever append children after their parent,
                // so indices strictly increase along every root→leaf path;
                // enforcing that here makes a cyclic (corrupt) checkpoint
                // fail at load instead of hanging `route()` forever
                if left <= idx || right <= idx {
                    return Err(anyhow!("split children must come after their parent"));
                }
                let feature = pusize(field(split, "feature")?, "feature")?;
                if feature >= n_features {
                    return Err(anyhow!("split feature out of range"));
                }
                nodes.push(Node::Split {
                    feature,
                    threshold: pf64(field(split, "threshold")?, "threshold")?,
                    left: left as u32,
                    right: right as u32,
                });
            } else {
                return Err(anyhow!("tree node: expected \"leaf\" or \"split\""));
            }
        }
        let root = pusize(field(j, "root")?, "root")?;
        if root >= nodes.len() {
            return Err(anyhow!("root index out of range"));
        }
        let mut pending = Vec::new();
        for item in parr(field(j, "pending")?, "pending")? {
            let idx = pusize(item, "pending")?;
            if idx >= nodes.len() {
                return Err(anyhow!("pending leaf index out of range"));
            }
            pending.push(idx as u32);
        }
        let backend = options.split_backend.instantiate();
        Ok(HoeffdingTreeRegressor {
            nodes,
            root: root as u32,
            n_features,
            options,
            factory: Arc::from(spec.to_factory()),
            criterion: Arc::from(criterion),
            n_splits: pusize(field(j, "n_splits")?, "n_splits")?,
            observer_label: label.to_string(),
            rng: rng_from(field(j, "rng")?, "rng")?,
            backend,
            pending,
            learns_since_sync: 0,
        })
    }

    /// Sum of observer elements across all leaves (paper memory metric).
    pub fn total_elements(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| match n {
                Node::Leaf(l) => l.n_elements(),
                _ => 0,
            })
            .sum()
    }

    /// Leaves that still hold observers (can still attempt splits).
    pub fn n_active_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf(l) if l.is_active()))
            .count()
    }

    /// Memory-governance step (a) ([`crate::govern`]): compact every
    /// active leaf's Quantization Observers down to at most
    /// `target_slots` slots each ([`QuantizationObserver::compact`] —
    /// exact under the paper's mergeable `VarStats`, Sec. 3). Non-QO
    /// observers are left untouched (their memory yields only to
    /// eviction). Returns how many observers actually shrank.
    ///
    /// Leaves are copy-on-written only when at least one of their
    /// observers needs compacting, so published snapshots sharing the
    /// other leaves stay shared.
    ///
    /// [`QuantizationObserver::compact`]:
    /// crate::observer::QuantizationObserver::compact
    pub fn compact_observers(&mut self, target_slots: usize) -> usize {
        let target = target_slots.max(2);
        let mut compacted = 0;
        for node in &mut self.nodes {
            let Node::Leaf(leaf) = node else { continue };
            let needs = leaf.observers.as_ref().is_some_and(|obs| {
                obs.iter().any(|o| {
                    o.as_qo()
                        .is_some_and(|q| q.radius().is_some() && q.n_elements() > target)
                })
            });
            if !needs {
                continue;
            }
            let leaf = Arc::make_mut(leaf);
            if let Some(observers) = &mut leaf.observers {
                for ao in observers.iter_mut() {
                    if let Some(q) = ao.as_qo_mut() {
                        if q.compact(target) > 0 {
                            compacted += 1;
                        }
                    }
                }
            }
        }
        compacted
    }

    /// Memory-governance step (b) ([`crate::govern`]): deactivate the
    /// observers of the `n` coldest active leaves — smallest
    /// `weight_since_attempt`, i.e. the leaves farthest from their next
    /// split attempt. An evicted leaf keeps predicting (stats + linear
    /// model survive) but can never split again, exactly like a leaf
    /// frozen at `max_depth`; checkpoints encode it as `observers: null`
    /// and deltas carry the shrink like any other touched leaf. Ties
    /// break on arena index so governance is deterministic. Returns how
    /// many leaves were evicted.
    pub fn evict_coldest(&mut self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        let mut cold: Vec<(f64, usize)> = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, node)| match node {
                Node::Leaf(l) if l.is_active() => Some((l.weight_since_attempt, i)),
                _ => None,
            })
            .collect();
        cold.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        let mut evicted = 0;
        for &(_, idx) in cold.iter().take(n) {
            let Node::Leaf(leaf) = &mut self.nodes[idx] else { unreachable!() };
            Arc::make_mut(leaf).observers = None;
            evicted += 1;
        }
        evicted
    }

    /// Approximate resident bytes: the node arena plus every leaf's
    /// observers, monitored list and linear model (capacity-based, so it
    /// tracks what the allocator actually holds).
    pub fn mem_bytes(&self) -> usize {
        let mut bytes = std::mem::size_of::<Self>()
            + self.nodes.capacity() * std::mem::size_of::<Node>()
            + self.pending.capacity() * std::mem::size_of::<u32>();
        for node in &self.nodes {
            if let Node::Leaf(leaf) = node {
                bytes += leaf.mem_bytes();
            }
        }
        bytes
    }
}

impl Regressor for HoeffdingTreeRegressor {
    fn predict(&self, x: &[f64]) -> f64 {
        let Node::Leaf(leaf) = &self.nodes[self.route(x) as usize] else { unreachable!() };
        leaf.predict(x)
    }

    fn learn_one(&mut self, x: &[f64], y: f64) {
        if let Some(leaf_idx) = self.learn_routing(x, y) {
            self.attempt_split(leaf_idx);
        }
    }

    fn name(&self) -> String {
        format!("htr[{}]", self.observer_label)
    }

    fn n_elements(&self) -> usize {
        self.total_elements()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::leaf::LeafModelKind;
    use crate::common::Rng;
    use crate::eval::prequential::prequential;
    use crate::eval::Regressor;
    use crate::observer::{factory, paper_lineup, EBst, QuantizationObserver, RadiusPolicy};
    use crate::stream::synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};
    use crate::stream::{Friedman1, Stream};

    fn qo_factory() -> Box<dyn ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    fn ebst_factory() -> Box<dyn ObserverFactory> {
        factory("E-BST", || Box::new(EBst::new()))
    }

    #[test]
    fn single_leaf_predicts_mean() {
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            qo_factory(),
        );
        for y in [2.0, 4.0] {
            tree.learn_one(&[0.0], y);
        }
        assert_eq!(tree.n_leaves(), 1);
        assert!((tree.predict(&[0.0]) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn splits_on_obvious_step() {
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            qo_factory(),
        );
        let mut rng = Rng::new(51);
        // single feature => no runner-up merit, so the split has to wait
        // for the tie-break: eps < tau needs n >= ln(1/delta)/(2 tau^2)
        // ~= 3224 with the defaults.
        for _ in 0..4000 {
            let x = rng.uniform(-1.0, 1.0);
            tree.learn_one(&[x], if x <= 0.0 { -5.0 } else { 5.0 });
        }
        assert!(tree.n_splits() >= 1, "tree never split");
        assert!(tree.predict(&[-0.5]) < -3.0);
        assert!(tree.predict(&[0.5]) > 3.0);
    }

    #[test]
    fn no_split_on_pure_noise() {
        let mut tree = HoeffdingTreeRegressor::new(
            2,
            HtrOptions::default(),
            ebst_factory(),
        );
        let mut rng = Rng::new(53);
        let n = 5000;
        for _ in 0..n {
            tree.learn_one(&[rng.f64(), rng.f64()], rng.normal(0.0, 1.0));
        }
        // Hoeffding trees do make some spurious splits on pure noise (the
        // merit-ratio test occasionally separates by chance); the invariant
        // is that growth stays far below the attempt budget n/grace.
        let attempts = n / tree.options.grace_period;
        assert!(
            tree.n_splits() <= attempts / 2,
            "splits={} attempts={attempts}",
            tree.n_splits()
        );
    }

    #[test]
    fn picks_the_informative_feature() {
        let mut tree = HoeffdingTreeRegressor::new(
            3,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            ebst_factory(),
        );
        let mut rng = Rng::new(55);
        for _ in 0..4000 {
            let x = [rng.f64(), rng.f64(), rng.f64()];
            // only feature 1 matters
            tree.learn_one(&x, if x[1] <= 0.5 { 0.0 } else { 10.0 });
        }
        assert!(tree.n_splits() >= 1);
        let Node::Split { feature, threshold, .. } = &tree.nodes[tree.root as usize] else {
            panic!("root should have split")
        };
        assert_eq!(*feature, 1);
        assert!((threshold - 0.5).abs() < 0.1, "threshold={threshold}");
    }

    #[test]
    fn max_depth_freezes_leaves() {
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions {
                max_depth: 1,
                leaf_model: LeafModelKind::Mean,
                ..Default::default()
            },
            qo_factory(),
        );
        let mut rng = Rng::new(57);
        for _ in 0..10_000 {
            let x = rng.uniform(-1.0, 1.0);
            // nested steps that would invite deep splitting
            let y = if x <= 0.0 {
                if x <= -0.5 {
                    -2.0
                } else {
                    -1.0
                }
            } else if x <= 0.5 {
                1.0
            } else {
                2.0
            };
            tree.learn_one(&[x], y);
        }
        assert!(tree.depth() <= 1);
        assert_eq!(tree.total_elements(), 0, "frozen leaves must not store elements");
    }

    #[test]
    fn tree_beats_mean_on_friedman() {
        let opts = HtrOptions::default();
        let mut tree = HoeffdingTreeRegressor::new(10, opts, qo_factory());
        let mut mean = crate::eval::MeanRegressor::new();
        let n = 30_000;
        let r_tree =
            prequential(&mut tree, &mut Friedman1::new(61, 1.0), n, 0);
        let r_mean =
            prequential(&mut mean, &mut Friedman1::new(61, 1.0), n, 0);
        assert!(
            r_tree.metrics.rmse() < 0.8 * r_mean.metrics.rmse(),
            "tree rmse {} vs mean rmse {}",
            r_tree.metrics.rmse(),
            r_mean.metrics.rmse()
        );
        assert!(r_tree.metrics.r2() > 0.5, "r2={}", r_tree.metrics.r2());
    }

    #[test]
    fn all_paper_observers_work_inside_the_tree() {
        for fac in paper_lineup() {
            let name = fac.name();
            let mut tree = HoeffdingTreeRegressor::new(
                2,
                HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
                fac,
            );
            let mut stream = SyntheticRegression::new(
                Distribution::Normal { mu: 0.0, sigma: 1.0 },
                TargetFn::Linear,
                NoiseSpec::NONE,
                2,
                63,
            );
            for inst in stream.take_vec(3000) {
                tree.learn_one(&inst.x, inst.y);
            }
            assert!(tree.n_splits() >= 1, "{name}: never split");
        }
    }

    #[test]
    fn subspace_tree_learns_and_splits_on_monitored_features() {
        use crate::tree::subspace::SubspaceSize;
        let mut tree = HoeffdingTreeRegressor::new(
            5,
            HtrOptions {
                leaf_model: LeafModelKind::Mean,
                subspace: SubspaceSize::Fixed(2),
                seed: 7,
                ..Default::default()
            },
            qo_factory(),
        );
        let mut rng = Rng::new(71);
        for _ in 0..20_000 {
            let x: Vec<f64> = (0..5).map(|_| rng.uniform(-1.0, 1.0)).collect();
            // every feature is informative, so any 2-feature subspace can split
            let y: f64 = x.iter().map(|v| if *v <= 0.0 { 0.0 } else { 1.0 }).sum();
            tree.learn_one(&x, y);
        }
        assert!(tree.n_splits() >= 1, "subspace tree never split");
        // every leaf monitors exactly 2 of the 5 features
        for node in &tree.nodes {
            if let Node::Leaf(leaf) = node {
                assert_eq!(leaf.monitored.len(), 2);
                assert!(leaf.monitored.iter().all(|&f| f < 5));
            }
        }
    }

    #[test]
    fn subspace_trees_deterministic_per_seed() {
        use crate::tree::subspace::SubspaceSize;
        let build = || {
            HoeffdingTreeRegressor::new(
                4,
                HtrOptions {
                    subspace: SubspaceSize::Sqrt,
                    seed: 99,
                    ..Default::default()
                },
                qo_factory(),
            )
        };
        let mut a = build();
        let mut b = build();
        let mut rng = Rng::new(73);
        for _ in 0..6000 {
            let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = 3.0 * x[0] - x[2];
            a.learn_one(&x, y);
            b.learn_one(&x, y);
        }
        assert_eq!(a.n_splits(), b.n_splits());
        let probe = [0.3, -0.4, 0.9, 0.1];
        assert_eq!(a.predict(&probe).to_bits(), b.predict(&probe).to_bits());
    }

    #[test]
    fn native_batch_backend_bit_identical_to_per_observer() {
        use crate::runtime::backend::SplitBackendKind;
        let build = |kind: SplitBackendKind| {
            HoeffdingTreeRegressor::new(
                5,
                HtrOptions { split_backend: kind, ..Default::default() },
                qo_factory(),
            )
        };
        let mut a = build(SplitBackendKind::PerObserver);
        let mut b = build(SplitBackendKind::NativeBatch);
        let mut rng = Rng::new(91);
        for _ in 0..12_000 {
            let x: Vec<f64> = (0..5).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = if x[2] <= 0.1 { -2.0 } else { 3.0 * x[0] };
            a.learn_one(&x, y);
            b.learn_one(&x, y);
        }
        assert!(a.n_splits() >= 1, "tree never grew");
        assert_eq!(a.n_splits(), b.n_splits());
        assert_eq!(a.n_nodes(), b.n_nodes());
        for _ in 0..100 {
            let probe: Vec<f64> = (0..5).map(|_| rng.uniform(-1.0, 1.0)).collect();
            assert_eq!(a.predict(&probe).to_bits(), b.predict(&probe).to_bits());
        }
    }

    #[test]
    fn deferred_queue_with_immediate_flush_matches_inline() {
        use crate::runtime::backend::NativeBatchBackend;
        let mut inline = HoeffdingTreeRegressor::new(2, HtrOptions::default(), qo_factory());
        let mut deferred = HoeffdingTreeRegressor::new(2, HtrOptions::default(), qo_factory());
        let backend = NativeBatchBackend;
        let mut rng = Rng::new(93);
        for _ in 0..6000 {
            let x = [rng.f64(), rng.f64()];
            let y = if x[0] <= 0.5 { 0.0 } else { 4.0 };
            inline.learn_one(&x, y);
            deferred.learn_one_deferred(&x, y);
            // flushing after every instance reproduces the inline timing
            deferred.flush_pending(&backend);
        }
        assert!(deferred.pending_attempts().is_empty());
        assert!(inline.n_splits() >= 1);
        assert_eq!(inline.n_splits(), deferred.n_splits());
        for _ in 0..50 {
            let probe = [rng.f64(), rng.f64()];
            assert_eq!(
                inline.predict(&probe).to_bits(),
                deferred.predict(&probe).to_bits()
            );
        }
    }

    #[test]
    fn deferred_queue_holds_attempts_until_flush() {
        use crate::runtime::backend::PerObserverBackend;
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            qo_factory(),
        );
        let mut rng = Rng::new(95);
        for _ in 0..5000 {
            let x = rng.uniform(-1.0, 1.0);
            tree.learn_one_deferred(&[x], if x <= 0.0 { -5.0 } else { 5.0 });
        }
        // attempts were queued, never evaluated: the tree must not split
        assert_eq!(tree.n_splits(), 0);
        assert!(!tree.pending_attempts().is_empty());
        tree.flush_pending(&PerObserverBackend);
        assert!(tree.pending_attempts().is_empty());
        // one flush resolves the (single) due root attempt
        assert!(tree.n_splits() >= 1, "flush must perform the queued attempt");
    }

    #[test]
    fn json_roundtrip_predicts_and_trains_identically() {
        use crate::tree::subspace::SubspaceSize;
        let mut tree = HoeffdingTreeRegressor::new(
            4,
            HtrOptions { subspace: SubspaceSize::Fixed(2), seed: 3, ..Default::default() },
            qo_factory(),
        );
        let mut rng = Rng::new(97);
        for _ in 0..6000 {
            let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
            tree.learn_one(&x, if x[1] <= 0.0 { -3.0 } else { 2.0 * x[0] });
        }
        assert!(tree.n_splits() >= 1, "tree must have structure to test");
        let text = tree.to_json().unwrap().to_compact();
        let mut back = HoeffdingTreeRegressor::from_json(
            &crate::common::json::Json::parse(&text).unwrap(),
        )
        .unwrap();
        assert_eq!(back.n_splits(), tree.n_splits());
        assert_eq!(back.n_nodes(), tree.n_nodes());
        assert_eq!(back.name(), tree.name());
        for _ in 0..50 {
            let probe: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
            assert_eq!(tree.predict(&probe).to_bits(), back.predict(&probe).to_bits());
        }
        // continued training (incl. future subspace draws from the
        // restored PRNG) stays bit-for-bit identical
        for _ in 0..6000 {
            let x: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = if x[1] <= 0.0 { -3.0 } else { 2.0 * x[0] };
            tree.learn_one(&x, y);
            back.learn_one(&x, y);
        }
        assert_eq!(back.n_splits(), tree.n_splits());
        assert_eq!(back.n_nodes(), tree.n_nodes());
        for _ in 0..50 {
            let probe: Vec<f64> = (0..4).map(|_| rng.uniform(-1.0, 1.0)).collect();
            assert_eq!(tree.predict(&probe).to_bits(), back.predict(&probe).to_bits());
        }
    }

    #[test]
    fn json_roundtrip_preserves_the_deferred_queue() {
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            qo_factory(),
        );
        let mut rng = Rng::new(41);
        for _ in 0..5000 {
            let x = rng.uniform(-1.0, 1.0);
            tree.learn_one_deferred(&[x], if x <= 0.0 { -5.0 } else { 5.0 });
        }
        assert!(!tree.pending_attempts().is_empty());
        let back = HoeffdingTreeRegressor::from_json(
            &crate::common::json::Json::parse(&tree.to_json().unwrap().to_compact())
                .unwrap(),
        )
        .unwrap();
        assert_eq!(back.pending_attempts(), tree.pending_attempts());
        let mut back = back;
        back.flush_pending(&crate::runtime::backend::PerObserverBackend);
        assert!(back.n_splits() >= 1, "restored queue must still resolve");
    }

    #[test]
    fn cyclic_checkpoint_is_rejected_at_load() {
        // corrupt a real checkpoint so a split points back at itself /
        // an ancestor: decode must fail instead of letting route() hang
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            qo_factory(),
        );
        let mut rng = Rng::new(13);
        for _ in 0..5000 {
            let x = rng.uniform(-1.0, 1.0);
            tree.learn_one(&[x], if x <= 0.0 { -5.0 } else { 5.0 });
        }
        assert!(tree.n_splits() >= 1, "need a split node to corrupt");
        let doc = tree.to_json().unwrap();
        let mut nodes: Vec<crate::common::json::Json> =
            doc.get("nodes").unwrap().as_arr().unwrap().to_vec();
        let mut corrupted = false;
        for node in &mut nodes {
            if let Some(split) = node.get("split") {
                let mut split = split.clone();
                split.set("left", crate::persist::codec::jusize(0));
                node.set("split", split);
                corrupted = true;
                break;
            }
        }
        assert!(corrupted, "checkpoint had no split node");
        let mut doc = doc;
        doc.set("nodes", crate::common::json::Json::Arr(nodes));
        let err = HoeffdingTreeRegressor::from_json(&doc);
        assert!(err.is_err(), "cyclic checkpoint must be rejected");
    }

    #[test]
    fn custom_closure_factory_is_rejected_at_save() {
        let tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions::default(),
            factory("my-custom-observer", || Box::new(EBst::new())),
        );
        let err = format!("{}", tree.to_json().unwrap_err());
        assert!(err.contains("my-custom-observer"), "{err}");
    }

    #[test]
    fn compact_observers_shrinks_memory_without_breaking_predictions() {
        let mut tree = HoeffdingTreeRegressor::new(
            2,
            HtrOptions::default(),
            factory("QO_0.001", || {
                Box::new(QuantizationObserver::with_radius(0.001))
            }),
        );
        let mut rng = Rng::new(201);
        for _ in 0..8000 {
            let x = [rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)];
            tree.learn_one(&x, if x[0] <= 0.2 { -1.0 } else { 3.0 });
        }
        let before = tree.mem_bytes();
        let probe = [0.4, -0.1];
        let pred = tree.predict(&probe);
        let compacted = tree.compact_observers(16);
        assert!(compacted > 0, "radius 0.001 must leave slots to compact");
        assert!(tree.mem_bytes() < before, "{} !< {before}", tree.mem_bytes());
        // predictions come from leaf stats/linear models, not observers
        assert_eq!(tree.predict(&probe).to_bits(), pred.to_bits());
        // idempotent at the same target
        assert_eq!(tree.compact_observers(16), 0);
        // compacted trees still checkpoint + restore
        let back = HoeffdingTreeRegressor::from_json(
            &crate::common::json::Json::parse(&tree.to_json().unwrap().to_compact())
                .unwrap(),
        )
        .unwrap();
        assert_eq!(back.predict(&probe).to_bits(), pred.to_bits());
    }

    #[test]
    fn evict_coldest_freezes_lightest_leaves_first() {
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            qo_factory(),
        );
        let mut rng = Rng::new(203);
        for _ in 0..8000 {
            let x = rng.uniform(-1.0, 1.0);
            tree.learn_one(&[x], if x <= 0.0 { -5.0 } else { 5.0 });
        }
        let active = tree.n_active_leaves();
        assert!(active >= 2, "need multiple leaves: {active}");
        let before = tree.mem_bytes();
        let probe = [-0.5];
        let pred = tree.predict(&probe);
        assert_eq!(tree.evict_coldest(1), 1);
        assert_eq!(tree.n_active_leaves(), active - 1);
        assert!(tree.mem_bytes() < before);
        assert_eq!(tree.predict(&probe).to_bits(), pred.to_bits());
        // evicting more than remain is bounded
        assert_eq!(tree.evict_coldest(usize::MAX), active - 1);
        assert_eq!(tree.n_active_leaves(), 0);
        assert_eq!(tree.total_elements(), 0);
        // further learning is safe and never splits again
        let splits = tree.n_splits();
        for _ in 0..3000 {
            let x = rng.uniform(-1.0, 1.0);
            tree.learn_one(&[x], if x <= 0.0 { -5.0 } else { 5.0 });
        }
        assert_eq!(tree.n_splits(), splits);
    }

    #[test]
    fn describe_renders_structure() {
        let mut tree = HoeffdingTreeRegressor::new(
            1,
            HtrOptions { leaf_model: LeafModelKind::Mean, ..Default::default() },
            qo_factory(),
        );
        let mut rng = Rng::new(65);
        for _ in 0..4000 {
            let x = rng.uniform(-1.0, 1.0);
            tree.learn_one(&[x], if x <= 0.0 { 0.0 } else { 1.0 });
        }
        let desc = tree.describe();
        assert!(desc.contains("if x[0] <="));
        assert!(desc.contains("leaf(n="));
    }
}
