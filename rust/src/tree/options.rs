//! Hoeffding Tree Regressor configuration.

pub use super::leaf::LeafModelKind;
pub use super::subspace::SubspaceSize;
pub use crate::runtime::backend::SplitBackendKind;

/// Hyper-parameters of [`super::HoeffdingTreeRegressor`]; defaults follow
/// FIMT-DD / river conventions.
#[derive(Clone, Copy, Debug)]
pub struct HtrOptions {
    /// Observations a leaf accumulates between split attempts.
    pub grace_period: usize,
    /// δ of the Hoeffding bound: confidence 1 − δ that the chosen split
    /// is truly the best.
    pub split_confidence: f64,
    /// τ tie-break: split anyway once ε < τ (merits effectively tied).
    pub tie_threshold: f64,
    /// Leaf prediction strategy.
    pub leaf_model: LeafModelKind,
    /// Depth cap; leaves at the cap stop monitoring (bounded memory).
    pub max_depth: usize,
    /// Learning rate for the leaf perceptron.
    pub leaf_lr: f64,
    /// Minimum fraction of the leaf's weight each branch must receive for
    /// a split to be admissible (guards against degenerate splits).
    pub min_branch_frac: f64,
    /// Random feature subspace each leaf monitors (ensemble hook; `All`
    /// reproduces the plain Hoeffding tree exactly).
    pub subspace: SubspaceSize,
    /// Seed of the tree's internal PRNG (subspace draws). Trees with the
    /// same options, seed and input stream are bit-for-bit identical.
    pub seed: u64,
    /// Split-query engine ([`crate::runtime::backend`]). `NativeBatch`
    /// (the default) is bit-identical to `PerObserver`; only the query
    /// path — and so the wall-clock — differs.
    pub split_backend: SplitBackendKind,
}

impl Default for HtrOptions {
    fn default() -> HtrOptions {
        HtrOptions {
            grace_period: 200,
            split_confidence: 1e-7,
            tie_threshold: 0.05,
            leaf_model: LeafModelKind::Adaptive,
            max_depth: usize::MAX,
            leaf_lr: 0.02,
            min_branch_frac: 0.01,
            subspace: SubspaceSize::All,
            seed: 0,
            split_backend: SplitBackendKind::default(),
        }
    }
}

impl HtrOptions {
    /// Hoeffding bound ε = √(R² ln(1/δ) / 2n) with R = 1 (merit *ratios*
    /// are compared, which live in [0, 1]).
    pub fn hoeffding_bound(&self, n: f64) -> f64 {
        if n <= 0.0 {
            return f64::INFINITY;
        }
        ((1.0 / self.split_confidence).ln() / (2.0 * n)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_shrinks_with_n() {
        let o = HtrOptions::default();
        let e1 = o.hoeffding_bound(200.0);
        let e2 = o.hoeffding_bound(2000.0);
        let e3 = o.hoeffding_bound(200_000.0);
        assert!(e1 > e2 && e2 > e3);
        // √(ln(1e7)/400) ≈ 0.2007
        assert!((e1 - 0.2007).abs() < 1e-3, "e1={e1}");
    }

    #[test]
    fn bound_at_zero_is_infinite() {
        assert!(HtrOptions::default().hoeffding_bound(0.0).is_infinite());
    }

    #[test]
    fn defaults_sane() {
        let o = HtrOptions::default();
        assert!(o.grace_period > 0);
        assert!(o.split_confidence > 0.0 && o.split_confidence < 1.0);
        assert!(o.tie_threshold > 0.0 && o.tie_threshold < 1.0);
    }
}
