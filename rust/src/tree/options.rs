//! Hoeffding Tree Regressor configuration.

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::persist::codec::{field, jf64, ju64, jusize, pf64, pstr, pu64, pusize};

pub use super::leaf::LeafModelKind;
pub use super::subspace::SubspaceSize;
pub use crate::runtime::backend::SplitBackendKind;

/// Hyper-parameters of [`super::HoeffdingTreeRegressor`]; defaults follow
/// FIMT-DD / river conventions.
#[derive(Clone, Copy, Debug)]
pub struct HtrOptions {
    /// Observations a leaf accumulates between split attempts.
    pub grace_period: usize,
    /// δ of the Hoeffding bound: confidence 1 − δ that the chosen split
    /// is truly the best.
    pub split_confidence: f64,
    /// τ tie-break: split anyway once ε < τ (merits effectively tied).
    pub tie_threshold: f64,
    /// Leaf prediction strategy.
    pub leaf_model: LeafModelKind,
    /// Depth cap; leaves at the cap stop monitoring (bounded memory).
    pub max_depth: usize,
    /// Learning rate for the leaf perceptron.
    pub leaf_lr: f64,
    /// Minimum fraction of the leaf's weight each branch must receive for
    /// a split to be admissible (guards against degenerate splits).
    pub min_branch_frac: f64,
    /// Random feature subspace each leaf monitors (ensemble hook; `All`
    /// reproduces the plain Hoeffding tree exactly).
    pub subspace: SubspaceSize,
    /// Seed of the tree's internal PRNG (subspace draws). Trees with the
    /// same options, seed and input stream are bit-for-bit identical.
    pub seed: u64,
    /// Split-query engine ([`crate::runtime::backend`]). `NativeBatch`
    /// (the default) is bit-identical to `PerObserver`; only the query
    /// path — and so the wall-clock — differs.
    pub split_backend: SplitBackendKind,
}

impl Default for HtrOptions {
    fn default() -> HtrOptions {
        HtrOptions {
            grace_period: 200,
            split_confidence: 1e-7,
            tie_threshold: 0.05,
            leaf_model: LeafModelKind::Adaptive,
            max_depth: usize::MAX,
            leaf_lr: 0.02,
            min_branch_frac: 0.01,
            subspace: SubspaceSize::All,
            seed: 0,
            split_backend: SplitBackendKind::default(),
        }
    }
}

impl HtrOptions {
    /// Hoeffding bound ε = √(R² ln(1/δ) / 2n) with R = 1 (merit *ratios*
    /// are compared, which live in [0, 1]).
    pub fn hoeffding_bound(&self, n: f64) -> f64 {
        if n <= 0.0 {
            return f64::INFINITY;
        }
        ((1.0 / self.split_confidence).ln() / (2.0 * n)).sqrt()
    }

    /// Checkpoint encoding ([`crate::persist`]). `max_depth` and `seed`
    /// travel as decimal strings (`usize::MAX` and raw seeds exceed what
    /// an f64 JSON number represents exactly); enum knobs travel through
    /// their CLI labels.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("grace_period", jusize(self.grace_period))
            .set("split_confidence", jf64(self.split_confidence))
            .set("tie_threshold", jf64(self.tie_threshold))
            .set("leaf_model", self.leaf_model.label())
            .set("max_depth", jusize(self.max_depth))
            .set("leaf_lr", jf64(self.leaf_lr))
            .set("min_branch_frac", jf64(self.min_branch_frac))
            .set("subspace", self.subspace.label())
            .set("seed", ju64(self.seed))
            .set("split_backend", self.split_backend.label());
        o
    }

    /// Decode options written by [`HtrOptions::to_json`].
    pub fn from_json(j: &Json) -> Result<HtrOptions> {
        let leaf_model = pstr(field(j, "leaf_model")?, "leaf_model")?;
        let subspace = pstr(field(j, "subspace")?, "subspace")?;
        let split_backend = pstr(field(j, "split_backend")?, "split_backend")?;
        Ok(HtrOptions {
            grace_period: pusize(field(j, "grace_period")?, "grace_period")?,
            split_confidence: pf64(field(j, "split_confidence")?, "split_confidence")?,
            tie_threshold: pf64(field(j, "tie_threshold")?, "tie_threshold")?,
            leaf_model: LeafModelKind::parse(leaf_model)
                .ok_or_else(|| anyhow!("unknown leaf model {leaf_model:?}"))?,
            max_depth: pusize(field(j, "max_depth")?, "max_depth")?,
            leaf_lr: pf64(field(j, "leaf_lr")?, "leaf_lr")?,
            min_branch_frac: pf64(field(j, "min_branch_frac")?, "min_branch_frac")?,
            subspace: SubspaceSize::parse(subspace)
                .ok_or_else(|| anyhow!("unknown subspace {subspace:?}"))?,
            seed: pu64(field(j, "seed")?, "seed")?,
            split_backend: SplitBackendKind::parse(split_backend)
                .ok_or_else(|| anyhow!("unknown split backend {split_backend:?}"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_shrinks_with_n() {
        let o = HtrOptions::default();
        let e1 = o.hoeffding_bound(200.0);
        let e2 = o.hoeffding_bound(2000.0);
        let e3 = o.hoeffding_bound(200_000.0);
        assert!(e1 > e2 && e2 > e3);
        // √(ln(1e7)/400) ≈ 0.2007
        assert!((e1 - 0.2007).abs() < 1e-3, "e1={e1}");
    }

    #[test]
    fn bound_at_zero_is_infinite() {
        assert!(HtrOptions::default().hoeffding_bound(0.0).is_infinite());
    }

    #[test]
    fn json_roundtrip_covers_extreme_fields() {
        let opts = HtrOptions {
            grace_period: 123,
            split_confidence: 1e-9,
            tie_threshold: 0.07,
            leaf_model: LeafModelKind::Linear,
            max_depth: usize::MAX, // beyond f64's exact-integer range
            leaf_lr: 0.015,
            min_branch_frac: 0.02,
            subspace: SubspaceSize::Fraction(0.5),
            seed: u64::MAX - 7,
            split_backend: SplitBackendKind::PerObserver,
        };
        let text = opts.to_json().to_compact();
        let back =
            HtrOptions::from_json(&crate::common::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.grace_period, opts.grace_period);
        assert_eq!(back.split_confidence, opts.split_confidence);
        assert_eq!(back.leaf_model, opts.leaf_model);
        assert_eq!(back.max_depth, usize::MAX);
        assert_eq!(back.seed, u64::MAX - 7);
        assert_eq!(back.subspace, SubspaceSize::Fraction(0.5));
        assert_eq!(back.split_backend, SplitBackendKind::PerObserver);
    }

    #[test]
    fn defaults_sane() {
        let o = HtrOptions::default();
        assert!(o.grace_period > 0);
        assert!(o.split_confidence > 0.0 && o.split_confidence < 1.0);
        assert!(o.tie_threshold > 0.0 && o.tie_threshold < 1.0);
    }
}
