//! Parallel ensemble fitting.
//!
//! Ensemble members are trained from independent per-member state (their
//! own tree, PRNG and detectors), so member updates commute across
//! members — the only ordering that matters is each member's own view of
//! the instance sequence. This module exploits that with the same
//! leader/worker shape as [`crate::coordinator`]: the leader owns the
//! stream, batches instances, and **broadcasts** each batch (an `Arc`, so
//! instances are shared, not copied) to worker threads over bounded
//! channels; each worker owns a disjoint chunk of members and replays
//! every batch through them in order. A full channel blocks the leader —
//! backpressure, not unbounded buffering.
//!
//! Because every member consumes the identical instance sequence through
//! identical per-member state transitions, the parallel fit is
//! **bit-for-bit identical** to the sequential `learn_one` loop (asserted
//! end-to-end in `rust/tests/forest_e2e.rs`). This holds with batched
//! split queries too: a worker flushes each member's deferred attempts
//! right after that member's round ([`super::batch`]), while the
//! sequential ensemble flushes all members in one backend call — which
//! leaves are due is a pure function of per-member state (never thread
//! timing), and backend evaluation is independent per query, so both
//! schedules resolve every attempt identically.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::runtime::backend::SplitBackend;
use crate::stream::{Instance, Stream};

/// An ensemble whose members can be trained independently of each other.
///
/// Beyond the worker-thread fitting contract ([`fit_parallel`]), the trait
/// exposes the pieces the *sharded* forest runtime
/// ([`crate::coordinator::forest`]) needs: deferred-mode training, a
/// cross-member flush (one backend round-trip per shard per tick), and the
/// per-member vote the leader folds into the ensemble prediction.
pub trait ParallelEnsemble {
    type Member: Send;

    /// All members, as one mutable slice (chunked across workers).
    fn members_mut(&mut self) -> &mut [Self::Member];

    /// Advance one member by one instance (the member must not touch any
    /// state outside itself).
    fn learn_member(member: &mut Self::Member, x: &[f64], y: f64);

    /// Advance one member by one instance in deferred-attempt mode: due
    /// split attempts queue on the member's trees instead of resolving
    /// inline (callers batch them through [`Self::flush_members`]).
    fn train_member(member: &mut Self::Member, x: &[f64], y: f64);

    /// Resolve every queued split attempt across `members` through **one**
    /// `backend.best_splits` call. Returns whether the backend was invoked
    /// (false = nothing was pending). Bit-identical to flushing members one
    /// by one: which leaves are due is per-member state and backend
    /// evaluation is independent per query.
    fn flush_members(members: &mut [&mut Self::Member], backend: &dyn SplitBackend) -> bool;

    /// The ensemble's shared split-query engine (cloned into each shard).
    fn split_backend(&self) -> Arc<dyn SplitBackend>;

    /// The member's current prediction (its vote, whether trained or not).
    fn member_predict(member: &Self::Member, x: &[f64]) -> f64;

    /// Whether the member has trained on at least one instance. Untrained
    /// members are excluded from the ensemble vote
    /// ([`crate::forest::fold_votes`]).
    fn member_trained(member: &Self::Member) -> bool;

    /// The member's recent prequential error, consumed by the
    /// accuracy-weighted vote ([`crate::forest::vote::fold_votes_weighted`]).
    /// Ignored unless [`Self::weighted_vote`] is on; the default suits
    /// ensembles that never weight.
    fn member_recent_err(_member: &Self::Member) -> f64 {
        0.0
    }

    /// Whether the ensemble folds votes by inverse recent error. The
    /// sharded leader ([`crate::coordinator::forest`]) consults this so
    /// its merged vote replays exactly the fold the sequential `predict`
    /// uses.
    fn weighted_vote(&self) -> bool {
        false
    }
}

/// The shared leader loop: pull up to `max_instances` from `stream`,
/// batch them, and broadcast every batch (an `Arc`, shared not copied) to
/// all `senders`, blocking on full channels (backpressure). `wrap` turns
/// the shared batch into the channel's message type — identity for
/// [`fit_parallel`], the train request for the sharded coordinator
/// ([`crate::coordinator::forest`]). Returns how many instances were sent.
pub(crate) fn broadcast_batches<T>(
    stream: &mut dyn Stream,
    max_instances: usize,
    batch_size: usize,
    senders: &[mpsc::SyncSender<T>],
    wrap: impl Fn(Arc<Vec<Instance>>) -> T,
) -> usize {
    let mut batch = Vec::with_capacity(batch_size);
    let mut sent = 0usize;
    while sent < max_instances {
        let Some(inst) = stream.next_instance() else { break };
        batch.push(inst);
        sent += 1;
        if batch.len() >= batch_size {
            let full = Arc::new(std::mem::replace(
                &mut batch,
                Vec::with_capacity(batch_size),
            ));
            for tx in senders {
                tx.send(wrap(full.clone())).expect("worker shard died");
            }
        }
    }
    if !batch.is_empty() {
        let last = Arc::new(batch);
        for tx in senders {
            tx.send(wrap(last.clone())).expect("worker shard died");
        }
    }
    sent
}

/// Tuning knobs of the parallel fit.
#[derive(Clone, Copy, Debug)]
pub struct ParallelFitConfig {
    /// Worker threads (clamped to the member count; 0 = all cores).
    pub n_workers: usize,
    /// Instances per broadcast message.
    pub batch_size: usize,
    /// Bounded channel depth in batches (backpressure window).
    pub channel_capacity: usize,
}

impl Default for ParallelFitConfig {
    fn default() -> ParallelFitConfig {
        ParallelFitConfig { n_workers: 0, batch_size: 256, channel_capacity: 8 }
    }
}

/// Outcome of a parallel fit.
#[derive(Clone, Debug)]
pub struct ParallelFitReport {
    pub instances: usize,
    pub seconds: f64,
    pub n_workers: usize,
    /// Instances replayed per worker (every worker sees the full stream).
    pub per_worker: Vec<usize>,
}

impl ParallelFitReport {
    pub fn throughput(&self) -> f64 {
        crate::common::timing::throughput(self.instances, self.seconds)
    }
}

/// Train `ensemble` on up to `max_instances` of `stream` with members
/// spread across worker threads. Equivalent to calling the ensemble's
/// sequential learn loop instance by instance, only faster.
pub fn fit_parallel<E: ParallelEnsemble>(
    ensemble: &mut E,
    stream: &mut dyn Stream,
    max_instances: usize,
    config: ParallelFitConfig,
) -> ParallelFitReport {
    let members = ensemble.members_mut();
    let n_members = members.len();
    assert!(n_members >= 1, "cannot fit an empty ensemble");
    let available = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let workers = if config.n_workers == 0 { available } else { config.n_workers };
    let workers = workers.clamp(1, n_members);
    let batch_size = config.batch_size.max(1);
    let start = Instant::now();

    let (sent, per_worker) = std::thread::scope(|scope| {
        let mut senders: Vec<mpsc::SyncSender<Arc<Vec<Instance>>>> = Vec::new();
        let mut handles = Vec::new();
        // Balanced chunking: ceil-sized chunks can exhaust the members
        // before the worker budget (6 members over 4 workers would yield
        // chunks of 2+2+2 and only 3 threads). Distribute the remainder so
        // exactly `workers` chunks exist, each of size base or base + 1.
        let base = n_members / workers;
        let extra = n_members % workers;
        let mut rest = members;
        for w in 0..workers {
            let size = base + usize::from(w < extra);
            let (chunk, tail) = rest.split_at_mut(size);
            rest = tail;
            let (tx, rx) = mpsc::sync_channel::<Arc<Vec<Instance>>>(
                config.channel_capacity.max(1),
            );
            senders.push(tx);
            handles.push(scope.spawn(move || {
                let mut count = 0usize;
                while let Ok(batch) = rx.recv() {
                    for inst in batch.iter() {
                        for member in chunk.iter_mut() {
                            E::learn_member(member, &inst.x, inst.y);
                        }
                    }
                    count += batch.len();
                }
                count
            }));
        }

        // leader loop: batch and broadcast (blocking on full channels)
        let sent = broadcast_batches(stream, max_instances, batch_size, &senders, |b| b);
        drop(senders); // close channels: workers drain and return

        let per_worker: Vec<usize> =
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
        (sent, per_worker)
    });

    ParallelFitReport {
        instances: sent,
        seconds: start.elapsed().as_secs_f64(),
        n_workers: per_worker.len(),
        per_worker,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Regressor;
    use crate::forest::bagging::OnlineBaggingRegressor;
    use crate::observer::{factory, ObserverFactory, QuantizationObserver, RadiusPolicy};
    use crate::stream::Friedman1;
    use crate::tree::HtrOptions;

    fn qo_factory() -> Box<dyn ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    fn bag(seed: u64) -> OnlineBaggingRegressor {
        OnlineBaggingRegressor::new(10, 4, 2.0, HtrOptions::default(), qo_factory(), seed)
    }

    #[test]
    fn parallel_fit_equals_sequential_fit() {
        let n = 3000;
        let mut sequential = bag(11);
        let mut stream = Friedman1::new(99, 1.0);
        for _ in 0..n {
            let inst = stream.next_instance().unwrap();
            sequential.learn_one(&inst.x, inst.y);
        }

        let mut parallel = bag(11);
        let report = fit_parallel(
            &mut parallel,
            &mut Friedman1::new(99, 1.0),
            n,
            ParallelFitConfig { n_workers: 3, ..Default::default() },
        );
        assert_eq!(report.instances, n);
        assert!(report.per_worker.iter().all(|&c| c == n));

        let mut probe_stream = Friedman1::new(123, 0.0);
        for _ in 0..50 {
            let inst = probe_stream.next_instance().unwrap();
            let a = sequential.predict(&inst.x);
            let b = parallel.predict(&inst.x);
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn non_divisible_ratio_spawns_exactly_the_requested_workers() {
        // 6 members over 4 workers used to ceil-chunk into 2+2+2 and spawn
        // only 3 threads while reporting 4; balanced chunks (2,2,1,1) must
        // spawn all 4, and the report must reflect the real thread count
        let mut ensemble =
            OnlineBaggingRegressor::new(10, 6, 2.0, HtrOptions::default(), qo_factory(), 8);
        let report = fit_parallel(
            &mut ensemble,
            &mut Friedman1::new(4, 1.0),
            600,
            ParallelFitConfig { n_workers: 4, batch_size: 64, ..Default::default() },
        );
        assert_eq!(report.n_workers, 4);
        assert_eq!(report.per_worker.len(), 4);
        assert!(report.per_worker.iter().all(|&c| c == 600), "{:?}", report.per_worker);

        // chunking must not affect the trained model (members are
        // independent): same seed fitted sequentially is bit-identical
        let mut sequential =
            OnlineBaggingRegressor::new(10, 6, 2.0, HtrOptions::default(), qo_factory(), 8);
        let mut stream = Friedman1::new(4, 1.0);
        for _ in 0..600 {
            let inst = stream.next_instance().unwrap();
            sequential.learn_one(&inst.x, inst.y);
        }
        let mut probe = Friedman1::new(40, 0.0);
        for _ in 0..50 {
            let inst = probe.next_instance().unwrap();
            assert_eq!(
                sequential.predict(&inst.x).to_bits(),
                ensemble.predict(&inst.x).to_bits()
            );
        }
    }

    #[test]
    fn worker_count_clamps_to_members() {
        let mut ensemble = bag(5);
        let report = fit_parallel(
            &mut ensemble,
            &mut Friedman1::new(1, 1.0),
            500,
            ParallelFitConfig { n_workers: 64, batch_size: 32, ..Default::default() },
        );
        assert_eq!(report.n_workers, 4); // 4 members
        assert_eq!(report.per_worker.len(), 4);
    }

    #[test]
    fn bounded_stream_stops_early() {
        struct Three(usize);
        impl crate::stream::Stream for Three {
            fn next_instance(&mut self) -> Option<Instance> {
                if self.0 == 0 {
                    return None;
                }
                self.0 -= 1;
                Some(Instance { x: vec![0.0; 10], y: 1.0 })
            }
            fn n_features(&self) -> usize {
                10
            }
            fn name(&self) -> String {
                "three".into()
            }
        }
        let mut ensemble = bag(2);
        let report =
            fit_parallel(&mut ensemble, &mut Three(3), 1000, ParallelFitConfig::default());
        assert_eq!(report.instances, 3);
    }

    #[test]
    fn tiny_channel_capacity_exercises_backpressure() {
        let mut ensemble = bag(3);
        let report = fit_parallel(
            &mut ensemble,
            &mut Friedman1::new(2, 1.0),
            2000,
            ParallelFitConfig { n_workers: 2, batch_size: 8, channel_capacity: 1 },
        );
        assert_eq!(report.instances, 2000);
    }
}
