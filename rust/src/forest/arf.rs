//! Adaptive Random Forest Regressor (Gomes et al. 2017, regression
//! variant), on top of the QO-backed Hoeffding tree.
//!
//! Each member combines the three ARF ingredients:
//!
//! 1. **Online bagging** — Poisson(λ) instance weighting (Oza–Russell);
//! 2. **Per-leaf random feature subspaces** — via the
//!    [`crate::tree::subspace`] hook threaded through the tree;
//! 3. **Drift adaptation** — two [`Adwin`] detectors monitor the member's
//!    prequential absolute error: a sensitive one (δ_w) raises a
//!    *warning* and starts a background tree that trains in parallel on
//!    the same weighted stream; a conservative one (δ_d) signals *drift*
//!    and atomically swaps the background tree in (or restarts from
//!    scratch when no background exists yet).
//!
//! Every member owns its PRNG and detectors, so member updates commute:
//! [`crate::forest::parallel::fit_parallel`] trains members on worker
//! threads with bit-for-bit the same result as the sequential loop.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::common::Rng;
use crate::eval::Regressor;
use crate::observer::{ArcFactory, ObserverFactory, ObserverSpec};
use crate::persist::codec::{
    field, jf64, ju64, jusize, parr, pbool, pf64, pstr, pu64, pusize, rng_from,
    rng_to_json,
};
use crate::runtime::backend::SplitBackend;
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::adwin::Adwin;
use super::batch::flush_split_attempts;
use super::parallel::ParallelEnsemble;
use super::vote::{fold_votes, fold_votes_weighted};
use crate::tree::subspace::SubspaceSize;

/// Fading factor of the per-member recent-error estimate feeding the
/// accuracy-weighted vote (normalized EWMA; ~1/(1−λ) ≈ 100-instance
/// horizon, fast enough to re-rank members during drift recovery).
const VOTE_ERR_FADE: f64 = 0.99;

/// ARF hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct ArfOptions {
    /// Ensemble size (the paper-reproduction e2e contract uses ≥ 10).
    pub n_members: usize,
    /// Poisson λ of the online bagging (ARF convention: 6).
    pub lambda: f64,
    /// ADWIN δ of the warning detector (more sensitive).
    pub warning_delta: f64,
    /// ADWIN δ of the drift detector (more conservative).
    pub drift_delta: f64,
    /// Per-leaf feature subspace of every member tree.
    pub subspace: SubspaceSize,
    /// Base Hoeffding-tree options (its `subspace`/`seed` fields are
    /// overridden per member).
    pub tree: HtrOptions,
    /// Master seed; member PRNGs, tree seeds and background-tree seeds all
    /// derive from it deterministically.
    pub seed: u64,
    /// Fold the ensemble vote by inverse recent prequential error
    /// ([`fold_votes_weighted`]) instead of the flat trained-member mean —
    /// members still fitting the current concept outvote stale ones
    /// during drift recovery. CLI: `qostream forest --weighted-vote`.
    pub weighted_vote: bool,
}

impl Default for ArfOptions {
    fn default() -> ArfOptions {
        ArfOptions {
            n_members: 10,
            lambda: 6.0,
            warning_delta: 0.01,
            drift_delta: 0.001,
            subspace: SubspaceSize::Sqrt,
            tree: HtrOptions::default(),
            seed: 1,
            weighted_vote: false,
        }
    }
}

impl ArfOptions {
    /// Checkpoint encoding ([`crate::persist`]).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("n_members", jusize(self.n_members))
            .set("lambda", jf64(self.lambda))
            .set("warning_delta", jf64(self.warning_delta))
            .set("drift_delta", jf64(self.drift_delta))
            .set("subspace", self.subspace.label())
            .set("tree", self.tree.to_json())
            .set("seed", ju64(self.seed))
            .set("weighted_vote", self.weighted_vote);
        o
    }

    /// Decode options written by [`ArfOptions::to_json`].
    pub fn from_json(j: &Json) -> Result<ArfOptions> {
        let subspace = pstr(field(j, "subspace")?, "subspace")?;
        Ok(ArfOptions {
            n_members: pusize(field(j, "n_members")?, "n_members")?,
            lambda: pf64(field(j, "lambda")?, "lambda")?,
            warning_delta: pf64(field(j, "warning_delta")?, "warning_delta")?,
            drift_delta: pf64(field(j, "drift_delta")?, "drift_delta")?,
            subspace: SubspaceSize::parse(subspace)
                .ok_or_else(|| anyhow!("unknown subspace {subspace:?}"))?,
            tree: HtrOptions::from_json(field(j, "tree")?)?,
            seed: pu64(field(j, "seed")?, "seed")?,
            weighted_vote: pbool(field(j, "weighted_vote")?, "weighted_vote")?,
        })
    }
}

/// One forest member: foreground tree, optional background tree, and the
/// warning/drift detectors watching the member's own prequential error.
#[derive(Clone)]
pub struct ArfMember {
    pub tree: HoeffdingTreeRegressor,
    background: Option<HoeffdingTreeRegressor>,
    warning: Adwin,
    drift: Adwin,
    rng: Rng,
    n_features: usize,
    lambda: f64,
    tree_options: HtrOptions,
    factory: Arc<dyn ObserverFactory>,
    backend: Arc<dyn SplitBackend>,
    /// Whether the foreground tree has trained on ≥ 1 instance. Until it
    /// has, its prediction is the untrained prior mean and the prequential
    /// error must NOT seed the drift detectors (it inflates the window
    /// with "falling error" mass that has nothing to do with the stream).
    fg_trained: bool,
    /// Same, for the background tree (carried over when it is swapped in).
    bg_trained: bool,
    n_warnings: usize,
    n_drifts: usize,
    /// Recent prequential absolute error (EWMA, [`VOTE_ERR_FADE`]) feeding
    /// the accuracy-weighted vote. Deliberately NOT reset on drift swaps:
    /// the estimate is *about this member slot's current output quality*,
    /// and the ~100-instance horizon re-converges quickly either way.
    vote_err: f64,
    /// Whether `vote_err` has absorbed its first sample (the first error
    /// seeds the EWMA directly, so early weights are not inflated by the
    /// zero initialization).
    vote_seeded: bool,
}

impl ArfMember {
    fn fresh_tree(&mut self) -> HoeffdingTreeRegressor {
        let opts = HtrOptions { seed: self.rng.next_u64(), ..self.tree_options };
        HoeffdingTreeRegressor::new(
            self.n_features,
            opts,
            Box::new(ArcFactory::new(self.factory.clone())),
        )
    }

    /// One prequential step: monitor the member's error, Poisson-train the
    /// foreground (and background) tree in deferred-attempt mode, then
    /// react to detector signals. Due split attempts stay queued on the
    /// trees — the forest flushes all members through one batched backend
    /// call per round; [`Self::learn`] (the per-worker parallel path)
    /// flushes this member alone with bit-identical results.
    pub(crate) fn train_queued(&mut self, x: &[f64], y: f64) {
        // error BEFORE training (prequential), but only once the tree's
        // prediction reflects at least one observed instance
        let err = if self.fg_trained {
            Some((y - self.tree.predict(x)).abs())
        } else {
            None
        };
        let k = self.rng.poisson(self.lambda);
        for _ in 0..k {
            self.tree.learn_one_deferred(x, y);
        }
        if k > 0 {
            self.fg_trained = true;
        }
        if self.background.is_some() {
            let kb = self.rng.poisson(self.lambda);
            if let Some(bg) = &mut self.background {
                for _ in 0..kb {
                    bg.learn_one_deferred(x, y);
                }
                if kb > 0 {
                    self.bg_trained = true;
                }
            }
        }
        let Some(err) = err else { return };
        self.vote_err = if self.vote_seeded {
            VOTE_ERR_FADE * self.vote_err + (1.0 - VOTE_ERR_FADE) * err
        } else {
            err
        };
        self.vote_seeded = true;
        let warning = self.warning.update(err);
        let drift = self.drift.update(err);
        // Only a RISING error is degradation. A falling error is the tree
        // converging — ADWIN adapts its window to it, but discarding the
        // model would throw away exactly what produced the improvement.
        if drift && self.drift.rising() {
            // swap in the background tree (fresh restart when none trained
            // yet) and re-arm both detectors for the new concept
            let promoted_background = self.background.is_some();
            self.tree = match self.background.take() {
                Some(bg) => {
                    self.fg_trained = self.bg_trained;
                    bg
                }
                None => {
                    self.fg_trained = false;
                    self.fresh_tree()
                }
            };
            self.bg_trained = false;
            self.warning.reset();
            self.drift.reset();
            self.n_drifts += 1;
            if let Some(m) = crate::obs::m() {
                m.forest_drifts.inc();
                if promoted_background {
                    m.forest_bg_promotions.inc();
                }
            }
        } else if warning && self.warning.rising() && self.background.is_none() {
            self.background = Some(self.fresh_tree());
            self.bg_trained = false;
            self.n_warnings += 1;
            if let Some(m) = crate::obs::m() {
                m.forest_warnings.inc();
            }
        }
    }

    /// Whether any of this member's trees has a queued split attempt.
    fn has_pending(&self) -> bool {
        !self.tree.pending_attempts().is_empty()
            || self
                .background
                .as_ref()
                .is_some_and(|bg| !bg.pending_attempts().is_empty())
    }

    /// Flush this member's queued split attempts through its backend.
    fn flush(&mut self) {
        if !self.has_pending() {
            return; // hot path: attempts are due ~once per grace period
        }
        let mut trees: Vec<&mut HoeffdingTreeRegressor> = Vec::with_capacity(2);
        trees.push(&mut self.tree);
        if let Some(bg) = &mut self.background {
            trees.push(bg);
        }
        flush_split_attempts(self.backend.as_ref(), &mut trees);
    }

    /// The self-contained member step used by the parallel fitting path:
    /// train, then flush this member's own queue. Bit-identical to the
    /// sequential forest round (train all members, flush all at once)
    /// because backend evaluation is independent per query and members
    /// share no state.
    pub(crate) fn learn(&mut self, x: &[f64], y: f64) {
        self.train_queued(x, y);
        self.flush();
    }

    /// Recent error for the weighted vote: `+∞` until the EWMA has seen
    /// its first sample, so a member trained one instance ago folds with
    /// weight 0 instead of the maximal weight (see
    /// [`fold_votes_weighted`]).
    fn recent_err(&self) -> f64 {
        if self.vote_seeded {
            self.vote_err
        } else {
            f64::INFINITY
        }
    }
}

/// The Adaptive Random Forest Regressor.
#[derive(Clone)]
pub struct ArfRegressor {
    members: Vec<ArfMember>,
    options: ArfOptions,
    observer_label: String,
    /// Shared split-query engine: one batched call resolves every
    /// member's due attempts per [`Regressor::learn_one`] round.
    backend: Arc<dyn SplitBackend>,
    /// Instances absorbed since [`Self::mark_synced`] — runtime-only
    /// touched-state tracking for the serve/replication layer (not
    /// checkpointed; see [`HoeffdingTreeRegressor::learns_since_sync`]).
    learns_since_sync: u64,
}

impl ArfRegressor {
    pub fn new(
        n_features: usize,
        options: ArfOptions,
        factory: Box<dyn ObserverFactory>,
    ) -> ArfRegressor {
        assert!(options.n_members >= 1, "need at least one member");
        assert!(options.lambda > 0.0, "lambda must be positive");
        let observer_label = factory.name();
        let shared: Arc<dyn ObserverFactory> = Arc::from(factory);
        let backend = options.tree.split_backend.build();
        let mut seeder = Rng::new(options.seed);
        let members = (0..options.n_members)
            .map(|i| {
                let mut rng = seeder.fork(i as u64);
                let tree_options = HtrOptions {
                    subspace: options.subspace,
                    seed: rng.next_u64(),
                    ..options.tree
                };
                ArfMember {
                    tree: HoeffdingTreeRegressor::new(
                        n_features,
                        tree_options,
                        Box::new(ArcFactory::new(shared.clone())),
                    ),
                    background: None,
                    warning: Adwin::new(options.warning_delta),
                    drift: Adwin::new(options.drift_delta),
                    rng,
                    n_features,
                    lambda: options.lambda,
                    tree_options,
                    factory: shared.clone(),
                    backend: backend.clone(),
                    fg_trained: false,
                    bg_trained: false,
                    n_warnings: 0,
                    n_drifts: 0,
                    vote_err: 0.0,
                    vote_seeded: false,
                }
            })
            .collect();
        ArfRegressor { members, options, observer_label, backend, learns_since_sync: 0 }
    }

    /// Instances absorbed since the last [`Self::mark_synced`]. The
    /// member-tree counters are folded in as a backstop, but they alone
    /// are NOT sufficient: member training mutates checkpointed state
    /// (PRNG words, detectors) even when the Poisson draw trains no tree,
    /// so any path that trains members outside [`Regressor::learn_one`]
    /// must report its instances via [`Self::note_learns`].
    pub fn learns_since_sync(&self) -> u64 {
        self.members
            .iter()
            .flat_map(|m| {
                std::iter::once(m.tree.learns_since_sync())
                    .chain(m.background.as_ref().map(|b| b.learns_since_sync()))
            })
            .fold(self.learns_since_sync, u64::max)
    }

    /// Record `n` instances trained through an external member-training
    /// path (e.g. the sharded coordinator), which bypasses
    /// [`Regressor::learn_one`] and would otherwise leave the
    /// touched-state counter stale when every Poisson draw was zero.
    pub fn note_learns(&mut self, n: u64) {
        self.learns_since_sync += n;
    }

    /// Reset the touched-state counters (ensemble and every member tree)
    /// after a snapshot/delta publication.
    pub fn mark_synced(&mut self) {
        self.learns_since_sync = 0;
        for member in &mut self.members {
            member.tree.mark_synced();
            if let Some(bg) = &mut member.background {
                bg.mark_synced();
            }
        }
    }

    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    /// Input dimensionality the forest was built for.
    pub fn n_features(&self) -> usize {
        self.members.first().map(|m| m.n_features).unwrap_or(0)
    }

    /// Warnings raised across all members (background trees started).
    pub fn n_warnings(&self) -> usize {
        self.members.iter().map(|m| m.n_warnings).sum()
    }

    /// Drifts signalled across all members (foreground trees swapped).
    pub fn n_drifts(&self) -> usize {
        self.members.iter().map(|m| m.n_drifts).sum()
    }

    /// Total splits across foreground trees.
    pub fn n_splits(&self) -> usize {
        self.members.iter().map(|m| m.tree.n_splits()).sum()
    }

    pub fn options(&self) -> &ArfOptions {
        &self.options
    }

    /// Resident heap footprint in bytes across all members (foreground and
    /// background trees) — the byte-level companion of
    /// [`Regressor::n_elements`], feeding the `model_mem_bytes` gauge.
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<ArfRegressor>()
            + self
                .members
                .iter()
                .map(|m| {
                    std::mem::size_of::<ArfMember>()
                        + m.tree.mem_bytes()
                        + m.background.as_ref().map(|b| b.mem_bytes()).unwrap_or(0)
                })
                .sum::<usize>()
    }

    /// Memory-governance step (a) across the whole forest
    /// ([`crate::govern`]): compact QO slot tables on every member's
    /// foreground *and* background tree
    /// ([`HoeffdingTreeRegressor::compact_observers`]). Returns how many
    /// observers shrank.
    pub fn compact_observers(&mut self, target_slots: usize) -> usize {
        let mut compacted = 0;
        for m in &mut self.members {
            compacted += m.tree.compact_observers(target_slots);
            if let Some(bg) = &mut m.background {
                compacted += bg.compact_observers(target_slots);
            }
        }
        compacted
    }

    /// Memory-governance step (b) across the whole forest
    /// ([`crate::govern`]): deactivate observers on the `per_tree`
    /// coldest leaves of every member tree (foreground and background;
    /// [`HoeffdingTreeRegressor::evict_coldest`]). Returns the total
    /// leaves evicted.
    pub fn evict_coldest(&mut self, per_tree: usize) -> usize {
        let mut evicted = 0;
        for m in &mut self.members {
            evicted += m.tree.evict_coldest(per_tree);
            if let Some(bg) = &mut m.background {
                evicted += bg.evict_coldest(per_tree);
            }
        }
        evicted
    }

    /// Leaves still holding observers across all member trees.
    pub fn n_active_leaves(&self) -> usize {
        self.members
            .iter()
            .map(|m| {
                m.tree.n_active_leaves()
                    + m.background.as_ref().map(|b| b.n_active_leaves()).unwrap_or(0)
            })
            .sum()
    }

    /// Memory-governance step (c) ([`crate::govern`]): drop the member
    /// with the worst recent prequential error — the same inverse-error
    /// EWMA that weights the vote ([`ArfMember::recent_err`]; unseeded
    /// members rank as `+∞`, so a member contributing nothing to the
    /// vote is pruned first). Ties keep the earliest member and prune
    /// the later one, so governance is deterministic. The last member is
    /// never pruned (a forest must keep predicting); `options.n_members`
    /// follows the live count so checkpoints stay self-consistent.
    /// Returns the pruned member's index, or `None` when only one
    /// member remains.
    pub fn prune_worst(&mut self) -> Option<usize> {
        if self.members.len() <= 1 {
            return None;
        }
        let mut worst = 0usize;
        for (i, m) in self.members.iter().enumerate() {
            if m.recent_err() > self.members[worst].recent_err()
                || (i > worst
                    && m.recent_err() == self.members[worst].recent_err())
            {
                worst = i;
            }
        }
        self.members.remove(worst);
        self.options.n_members = self.members.len();
        Some(worst)
    }

    /// Replace the shared split-query engine (e.g. an instrumented backend
    /// in tests); every member's flush handle is updated too.
    pub fn with_split_backend(mut self, backend: Arc<dyn SplitBackend>) -> ArfRegressor {
        for member in &mut self.members {
            member.backend = backend.clone();
        }
        self.backend = backend;
        self
    }

    /// Checkpoint encoding ([`crate::persist`]): options plus every
    /// member's complete state — foreground and background trees, both
    /// ADWIN detectors, the member PRNG and the vote-error estimate — so
    /// a restored forest predicts and keeps training bit-for-bit like the
    /// live one.
    pub fn to_json(&self) -> Result<Json> {
        let mut members = Vec::with_capacity(self.members.len());
        for m in &self.members {
            let mut o = Json::obj();
            o.set("tree", m.tree.to_json()?)
                .set(
                    "background",
                    match &m.background {
                        Some(bg) => bg.to_json()?,
                        None => Json::Null,
                    },
                )
                .set("warning", m.warning.to_json())
                .set("drift", m.drift.to_json())
                .set("rng", rng_to_json(&m.rng))
                .set("tree_options", m.tree_options.to_json())
                .set("fg_trained", m.fg_trained)
                .set("bg_trained", m.bg_trained)
                .set("n_warnings", jusize(m.n_warnings))
                .set("n_drifts", jusize(m.n_drifts))
                .set("vote_err", jf64(m.vote_err))
                .set("vote_seeded", m.vote_seeded);
            members.push(o);
        }
        let spec = ObserverSpec::from_label(&self.observer_label).ok_or_else(|| {
            anyhow!(
                "observer factory {:?} is not checkpointable",
                self.observer_label
            )
        })?;
        let n_features = self
            .members
            .first()
            .map(|m| m.n_features)
            .ok_or_else(|| anyhow!("forest has no members"))?;
        let mut o = Json::obj();
        o.set("options", self.options.to_json())
            .set("observer", spec.label())
            .set("n_features", jusize(n_features))
            .set("members", Json::Arr(members));
        Ok(o)
    }

    /// Decode a forest written by [`ArfRegressor::to_json`].
    pub fn from_json(j: &Json) -> Result<ArfRegressor> {
        let options = ArfOptions::from_json(field(j, "options")?)?;
        let label = pstr(field(j, "observer")?, "observer")?;
        let spec = ObserverSpec::from_label(label)
            .ok_or_else(|| anyhow!("unknown observer label {label:?}"))?;
        let shared: Arc<dyn ObserverFactory> = Arc::from(spec.to_factory());
        let backend = options.tree.split_backend.build();
        let n_features = pusize(field(j, "n_features")?, "n_features")?;
        let mut members = Vec::new();
        for m in parr(field(j, "members")?, "members")? {
            let background = match field(m, "background")? {
                Json::Null => None,
                bg => Some(HoeffdingTreeRegressor::from_json(bg)?),
            };
            members.push(ArfMember {
                tree: HoeffdingTreeRegressor::from_json(field(m, "tree")?)?,
                background,
                warning: Adwin::from_json(field(m, "warning")?)?,
                drift: Adwin::from_json(field(m, "drift")?)?,
                rng: rng_from(field(m, "rng")?, "rng")?,
                n_features,
                lambda: options.lambda,
                tree_options: HtrOptions::from_json(field(m, "tree_options")?)?,
                factory: shared.clone(),
                backend: backend.clone(),
                fg_trained: pbool(field(m, "fg_trained")?, "fg_trained")?,
                bg_trained: pbool(field(m, "bg_trained")?, "bg_trained")?,
                n_warnings: pusize(field(m, "n_warnings")?, "n_warnings")?,
                n_drifts: pusize(field(m, "n_drifts")?, "n_drifts")?,
                vote_err: pf64(field(m, "vote_err")?, "vote_err")?,
                vote_seeded: pbool(field(m, "vote_seeded")?, "vote_seeded")?,
            });
        }
        if members.is_empty() {
            return Err(anyhow!("forest checkpoint has no members"));
        }
        Ok(ArfRegressor {
            members,
            options,
            observer_label: label.to_string(),
            backend,
            learns_since_sync: 0,
        })
    }
}

impl Regressor for ArfRegressor {
    fn predict(&self, x: &[f64]) -> f64 {
        // only trained members vote: a fresh post-drift-swap tree predicts
        // the untrained prior mean and would drag the forest toward it
        if self.options.weighted_vote {
            fold_votes_weighted(
                self.members
                    .iter()
                    .map(|m| (m.tree.predict(x), m.fg_trained, m.recent_err())),
            )
        } else {
            fold_votes(self.members.iter().map(|m| (m.tree.predict(x), m.fg_trained)))
        }
    }

    fn learn_one(&mut self, x: &[f64], y: f64) {
        self.learns_since_sync += 1;
        for member in &mut self.members {
            member.train_queued(x, y);
        }
        if !self.members.iter().any(ArfMember::has_pending) {
            return; // hot path: attempts are due ~once per grace period
        }
        // one batched backend call resolves every member's due attempts
        let backend = self.backend.clone();
        let mut refs: Vec<&mut ArfMember> = self.members.iter_mut().collect();
        <ArfRegressor as ParallelEnsemble>::flush_members(&mut refs, backend.as_ref());
    }

    fn name(&self) -> String {
        format!("arf[{}x{}]", self.members.len(), self.observer_label)
    }

    fn n_elements(&self) -> usize {
        self.members
            .iter()
            .map(|m| {
                m.tree.total_elements()
                    + m.background.as_ref().map(|b| b.total_elements()).unwrap_or(0)
            })
            .sum()
    }
}

impl ParallelEnsemble for ArfRegressor {
    type Member = ArfMember;

    fn members_mut(&mut self) -> &mut [ArfMember] {
        &mut self.members
    }

    fn learn_member(member: &mut ArfMember, x: &[f64], y: f64) {
        member.learn(x, y);
    }

    fn train_member(member: &mut ArfMember, x: &[f64], y: f64) {
        member.train_queued(x, y);
    }

    fn flush_members(members: &mut [&mut ArfMember], backend: &dyn SplitBackend) -> bool {
        if !members.iter().any(|m| m.has_pending()) {
            return false; // hot path: attempts are due ~once per grace period
        }
        let mut trees: Vec<&mut HoeffdingTreeRegressor> =
            Vec::with_capacity(members.len() * 2);
        for member in members.iter_mut() {
            trees.push(&mut member.tree);
            if let Some(bg) = &mut member.background {
                trees.push(bg);
            }
        }
        flush_split_attempts(backend, &mut trees);
        true
    }

    fn split_backend(&self) -> Arc<dyn SplitBackend> {
        self.backend.clone()
    }

    fn member_predict(member: &ArfMember, x: &[f64]) -> f64 {
        member.tree.predict(x)
    }

    fn member_trained(member: &ArfMember) -> bool {
        member.fg_trained
    }

    fn member_recent_err(member: &ArfMember) -> f64 {
        member.recent_err()
    }

    fn weighted_vote(&self) -> bool {
        self.options.weighted_vote
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::prequential::prequential;
    use crate::eval::MeanRegressor;
    use crate::observer::{factory, QuantizationObserver, RadiusPolicy};
    use crate::stream::{Friedman1, Stream};

    fn qo_factory() -> Box<dyn ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    fn small_arf(members: usize, seed: u64) -> ArfRegressor {
        ArfRegressor::new(
            10,
            ArfOptions { n_members: members, lambda: 3.0, seed, ..Default::default() },
            qo_factory(),
        )
    }

    #[test]
    fn learns_friedman_better_than_mean() {
        let n = 6000;
        let mut arf = small_arf(5, 17);
        let mut mean = MeanRegressor::new();
        let r_arf = prequential(&mut arf, &mut Friedman1::new(23, 1.0), n, 0);
        let r_mean = prequential(&mut mean, &mut Friedman1::new(23, 1.0), n, 0);
        assert!(
            r_arf.metrics.rmse() < 0.85 * r_mean.metrics.rmse(),
            "arf rmse {} vs mean {}",
            r_arf.metrics.rmse(),
            r_mean.metrics.rmse()
        );
        assert!(arf.n_splits() >= 1, "no member ever split");
    }

    #[test]
    fn stationary_stream_raises_no_drifts() {
        let mut arf = small_arf(4, 3);
        let mut stream = Friedman1::new(31, 1.0);
        for _ in 0..4000 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        // the error signal *improves* as trees learn (a one-sided change
        // ADWIN may legitimately track by shrinking), but conservative
        // drift detections must stay rare on a stationary concept
        assert!(
            arf.n_drifts() <= arf.n_members(),
            "{} drifts on a stationary stream",
            arf.n_drifts()
        );
    }

    #[test]
    fn no_detector_signals_on_a_short_prefix() {
        // satellite contract: the untrained tree's prior-mean error must
        // not seed the ADWIN windows, so a short stationary prefix raises
        // no warnings at all (the converging-tree error is falling, and
        // it only reaches the detectors once the tree has trained)
        let mut arf = ArfRegressor::new(
            10,
            ArfOptions { n_members: 5, seed: 11, ..Default::default() },
            qo_factory(),
        );
        let mut stream = Friedman1::new(13, 1.0);
        for _ in 0..300 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        assert_eq!(arf.n_warnings(), 0, "warmup error leaked into the detectors");
        assert_eq!(arf.n_drifts(), 0);
    }

    #[test]
    fn untrained_members_are_excluded_from_the_vote() {
        let mut arf = small_arf(4, 21);
        let mut stream = Friedman1::new(77, 1.0);
        for _ in 0..4000 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        let probe = [0.5; 10];
        let before = arf.predict(&probe);

        // simulate the post-drift swap when no background tree had trained
        // yet: the fresh foreground predicts the untrained prior mean
        let fresh = arf.members[0].fresh_tree();
        arf.members[0].tree = fresh;
        arf.members[0].fg_trained = false;
        let after = arf.predict(&probe);

        // the vote must be exactly the trained members' mean...
        let trained_mean =
            arf.members[1..].iter().map(|m| m.tree.predict(&probe)).sum::<f64>() / 3.0;
        assert_eq!(after.to_bits(), trained_mean.to_bits());
        // ...not the all-member average, which the fresh member's
        // prior-mean prediction drags toward 0
        let dragged = arf.members.iter().map(|m| m.tree.predict(&probe)).sum::<f64>()
            / arf.members.len() as f64;
        assert!(
            (after - before).abs() < (dragged - before).abs(),
            "swap dragged the vote: before {before}, after {after}, dragged {dragged}"
        );
    }

    #[test]
    fn fresh_forest_falls_back_to_the_flat_mean() {
        // no member has trained: the vote degrades to the flat mean of the
        // prior predictions instead of dividing by a zero trained-count
        let arf = small_arf(3, 9);
        let probe = [0.2; 10];
        let p = arf.predict(&probe);
        assert!(p.is_finite(), "untrained forest produced {p}");
        let flat =
            arf.members.iter().map(|m| m.tree.predict(&probe)).sum::<f64>() / 3.0;
        assert_eq!(p.to_bits(), flat.to_bits());
    }

    #[test]
    fn deterministic_per_seed() {
        let run = || {
            let mut arf = small_arf(3, 41);
            let mut stream = Friedman1::new(7, 1.0);
            for _ in 0..2000 {
                let inst = stream.next_instance().unwrap();
                arf.learn_one(&inst.x, inst.y);
            }
            arf.predict(&[0.4; 10])
        };
        assert_eq!(run().to_bits(), run().to_bits());
    }

    #[test]
    fn different_seeds_differ() {
        let run = |seed| {
            let mut arf = small_arf(3, seed);
            let mut stream = Friedman1::new(7, 1.0);
            for _ in 0..1500 {
                let inst = stream.next_instance().unwrap();
                arf.learn_one(&inst.x, inst.y);
            }
            arf.predict(&[0.4; 10])
        };
        assert_ne!(run(1).to_bits(), run(2).to_bits());
    }

    #[test]
    fn name_and_options() {
        let arf = small_arf(4, 1);
        assert_eq!(arf.name(), "arf[4xQO_s2]");
        assert_eq!(arf.n_members(), 4);
        assert_eq!(arf.options().lambda, 3.0);
    }

    #[test]
    fn json_roundtrip_predicts_and_trains_identically() {
        let mut arf = small_arf(3, 29);
        let mut stream = Friedman1::new(55, 1.0);
        for _ in 0..2500 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        let text = arf.to_json().unwrap().to_compact();
        let mut back =
            ArfRegressor::from_json(&crate::common::json::Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.name(), arf.name());
        assert_eq!(back.n_members(), arf.n_members());
        assert_eq!(back.n_splits(), arf.n_splits());
        let probe = [0.5; 10];
        assert_eq!(arf.predict(&probe).to_bits(), back.predict(&probe).to_bits());
        // continued training — including member Poisson draws, detector
        // updates and any drift swaps — stays bit-for-bit identical
        for _ in 0..2500 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
            back.learn_one(&inst.x, inst.y);
        }
        assert_eq!(back.n_splits(), arf.n_splits());
        assert_eq!(back.n_drifts(), arf.n_drifts());
        assert_eq!(arf.predict(&probe).to_bits(), back.predict(&probe).to_bits());
    }

    #[test]
    fn prune_worst_drops_the_least_accurate_member_and_roundtrips() {
        let mut arf = small_arf(4, 37);
        let mut stream = Friedman1::new(45, 1.0);
        for _ in 0..3000 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        // make member 1 unambiguously the worst
        arf.members[1].vote_err = 1e9;
        arf.members[1].vote_seeded = true;
        assert_eq!(arf.prune_worst(), Some(1));
        assert_eq!(arf.n_members(), 3);
        assert_eq!(arf.options().n_members, 3);
        assert_eq!(arf.name(), "arf[3xQO_s2]");
        let probe = [0.5; 10];
        assert!(arf.predict(&probe).is_finite());
        // a pruned forest checkpoints and restores bit-identically
        let back =
            ArfRegressor::from_json(&crate::common::json::Json::parse(
                &arf.to_json().unwrap().to_compact(),
            )
            .unwrap())
            .unwrap();
        assert_eq!(back.n_members(), 3);
        assert_eq!(back.predict(&probe).to_bits(), arf.predict(&probe).to_bits());
        // unseeded members (vote weight 0) are pruned before seeded ones
        arf.members[0].vote_seeded = false;
        assert_eq!(arf.prune_worst(), Some(0));
        // exact tie: the later member is the one pruned
        arf.members[0].vote_err = 0.5;
        arf.members[0].vote_seeded = true;
        arf.members[1].vote_err = 0.5;
        arf.members[1].vote_seeded = true;
        assert_eq!(arf.prune_worst(), Some(1), "later member pruned on ties");
        assert_eq!(arf.n_members(), 1);
        assert_eq!(arf.prune_worst(), None, "last member must survive");
        assert_eq!(arf.n_members(), 1);
    }

    #[test]
    fn forest_compact_and_evict_walk_every_member() {
        let mut arf = ArfRegressor::new(
            10,
            ArfOptions {
                n_members: 3,
                lambda: 3.0,
                seed: 91,
                tree: HtrOptions::default(),
                ..Default::default()
            },
            factory("QO_0.01", || {
                Box::new(QuantizationObserver::new(RadiusPolicy::Fixed(0.01)))
            }),
        );
        let mut stream = Friedman1::new(63, 1.0);
        for _ in 0..4000 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        let before = arf.mem_bytes();
        let probe = [0.4; 10];
        let pred = arf.predict(&probe);
        assert!(arf.compact_observers(8) > 0);
        assert!(arf.mem_bytes() < before);
        assert_eq!(arf.predict(&probe).to_bits(), pred.to_bits());
        let active = arf.n_active_leaves();
        assert!(active >= arf.n_members());
        assert!(arf.evict_coldest(1) >= arf.n_members());
        assert!(arf.n_active_leaves() < active);
        assert_eq!(arf.predict(&probe).to_bits(), pred.to_bits());
    }

    #[test]
    fn weighted_vote_flag_changes_only_the_fold() {
        let run = |weighted: bool| {
            let mut arf = ArfRegressor::new(
                10,
                ArfOptions {
                    n_members: 3,
                    lambda: 3.0,
                    seed: 41,
                    weighted_vote: weighted,
                    ..Default::default()
                },
                qo_factory(),
            );
            let mut stream = Friedman1::new(7, 1.0);
            for _ in 0..2000 {
                let inst = stream.next_instance().unwrap();
                arf.learn_one(&inst.x, inst.y);
            }
            arf
        };
        let flat = run(false);
        let weighted = run(true);
        // training is identical (the vote never feeds back into training)…
        assert_eq!(flat.n_splits(), weighted.n_splits());
        // …and the folds genuinely differ once member errors diverge
        let probe = [0.3; 10];
        assert_ne!(
            flat.predict(&probe).to_bits(),
            weighted.predict(&probe).to_bits()
        );
    }
}
