//! Online ensemble learning on top of the QO-backed Hoeffding trees.
//!
//! The paper's Quantization Observer makes per-instance observation cheap
//! enough that *aggressive* split attempting becomes affordable; the place
//! where that economy compounds is an **ensemble**, where every instance
//! fans out to many trees (Manapragada et al., "An Eager Splitting
//! Strategy for Online Decision Trees in Ensembles"). This subsystem
//! scales the single [`crate::tree::HoeffdingTreeRegressor`] into
//! competitive online forests:
//!
//! * [`adwin`] — the ADWIN drift detector (Bifet & Gavaldà 2007), built
//!   on the paper's Sec. 3 mergeable/subtractable [`crate::stats::VarStats`]
//!   estimators;
//! * [`subspace`] (re-exported from [`crate::tree::subspace`], where it
//!   lives so the tree layer stays ensemble-free) — per-leaf random
//!   feature subspaces via [`crate::tree::HtrOptions::subspace`];
//! * [`bagging`] — Oza–Russell online bagging with Poisson(λ) instance
//!   weighting;
//! * [`arf`] — the Adaptive Random Forest Regressor (Gomes et al. 2017):
//!   bagging + subspaces + per-member warning/drift detectors with
//!   background trees swapped in on drift;
//! * [`batch`] — cross-member batched split-attempt flushing: members
//!   train in deferred-attempt mode and every due leaf across the whole
//!   forest is answered through one
//!   [`crate::runtime::backend::SplitBackend`] call per round;
//! * [`vote`] — the shared ensemble vote: only *trained* members vote,
//!   and the fold order is fixed so the sharded-forest leader
//!   ([`crate::coordinator::forest`]) reproduces `predict` bit-for-bit;
//! * [`parallel`] — multi-core member fitting over the same bounded
//!   channel/backpressure machinery as [`crate::coordinator`], bit-for-bit
//!   identical to sequential training. For sharding members across
//!   leader/worker shards with one split round-trip per tick, see
//!   [`crate::coordinator::forest`].
//!
//! Both ensembles implement [`crate::eval::Regressor`], so the
//! prequential harness, the CLI (`qostream forest`) and the bench suite
//! drive them exactly like a single tree. Both also expose the
//! memory-governance walkers (`compact_observers` / `evict_coldest` /
//! `prune_worst`) that [`crate::govern`] escalates through to hold an
//! ensemble inside a byte budget — see `docs/MEMORY.md`.

pub mod adwin;
pub mod arf;
pub mod bagging;
pub mod batch;
pub mod parallel;
pub mod vote;

pub use crate::tree::subspace;
pub use crate::tree::subspace::{sample_subspace, SubspaceSize};

pub use adwin::Adwin;
pub use arf::{ArfOptions, ArfRegressor};
pub use bagging::OnlineBaggingRegressor;
pub use batch::flush_split_attempts;
pub use parallel::{fit_parallel, ParallelEnsemble, ParallelFitConfig, ParallelFitReport};
pub use vote::{fold_votes, fold_votes_weighted};
