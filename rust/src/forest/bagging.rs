//! Oza–Russell online bagging (Oza & Russell 2001) over QO-backed
//! Hoeffding tree regressors.
//!
//! Batch bagging gives every member a bootstrap resample of the data;
//! online, each arriving instance is instead shown to member `m` a random
//! `k ~ Poisson(λ)` times (λ = 1 reproduces the bootstrap in expectation;
//! ARF uses λ = 6 to accelerate early growth). Every member owns an
//! independent PRNG, so training members in parallel
//! ([`crate::forest::parallel`]) is bit-for-bit identical to the
//! sequential loop.

use std::sync::Arc;

use crate::common::Rng;
use crate::eval::Regressor;
use crate::observer::{ArcFactory, ObserverFactory};
use crate::runtime::backend::SplitBackend;
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::batch::flush_split_attempts;
use super::parallel::ParallelEnsemble;
use super::vote::fold_votes;

/// One bagged member: a tree plus its private Poisson weighting stream.
pub struct BagMember {
    pub tree: HoeffdingTreeRegressor,
    rng: Rng,
    lambda: f64,
    backend: Arc<dyn SplitBackend>,
    /// Whether the tree has trained on ≥ 1 instance — every Poisson draw
    /// can be zero early on, and an untrained tree's prior-mean prediction
    /// must not enter the ensemble vote.
    trained: bool,
}

impl BagMember {
    /// Train on one instance with Poisson(λ) importance (possibly zero
    /// times — the online analogue of being left out of the bootstrap),
    /// queueing due split attempts on the tree.
    pub(crate) fn train_queued(&mut self, x: &[f64], y: f64) {
        let k = self.rng.poisson(self.lambda);
        for _ in 0..k {
            self.tree.learn_one_deferred(x, y);
        }
        if k > 0 {
            self.trained = true;
        }
    }

    /// Self-contained member step (the parallel fitting path): train,
    /// then flush this member's queue through its backend. Bit-identical
    /// to the sequential forest round, which flushes all members at once.
    pub(crate) fn learn(&mut self, x: &[f64], y: f64) {
        self.train_queued(x, y);
        if !self.tree.pending_attempts().is_empty() {
            flush_split_attempts(self.backend.as_ref(), &mut [&mut self.tree]);
        }
    }
}

/// Online bagging ensemble of Hoeffding tree regressors.
pub struct OnlineBaggingRegressor {
    members: Vec<BagMember>,
    observer_label: String,
    /// Shared split-query engine: one batched call per `learn_one` round.
    backend: Arc<dyn SplitBackend>,
}

impl OnlineBaggingRegressor {
    /// Build `n_members` trees sharing one observer configuration. Member
    /// seeds (for both the Poisson stream and the tree's subspace draws)
    /// derive deterministically from `seed`.
    pub fn new(
        n_features: usize,
        n_members: usize,
        lambda: f64,
        tree_options: HtrOptions,
        factory: Box<dyn ObserverFactory>,
        seed: u64,
    ) -> OnlineBaggingRegressor {
        assert!(n_members >= 1, "need at least one member");
        assert!(lambda > 0.0, "lambda must be positive");
        let observer_label = factory.name();
        let shared: Arc<dyn ObserverFactory> = Arc::from(factory);
        let backend = tree_options.split_backend.build();
        let mut seeder = Rng::new(seed);
        let members = (0..n_members)
            .map(|i| {
                let mut rng = seeder.fork(i as u64);
                let opts = HtrOptions { seed: rng.next_u64(), ..tree_options };
                BagMember {
                    tree: HoeffdingTreeRegressor::new(
                        n_features,
                        opts,
                        Box::new(ArcFactory::new(shared.clone())),
                    ),
                    rng,
                    lambda,
                    backend: backend.clone(),
                    trained: false,
                }
            })
            .collect();
        OnlineBaggingRegressor { members, observer_label, backend }
    }

    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    /// Total splits across members (growth indicator).
    pub fn n_splits(&self) -> usize {
        self.members.iter().map(|m| m.tree.n_splits()).sum()
    }

    /// Replace the shared split-query engine (e.g. an instrumented backend
    /// in tests); every member's flush handle is updated too.
    pub fn with_split_backend(
        mut self,
        backend: Arc<dyn SplitBackend>,
    ) -> OnlineBaggingRegressor {
        for member in &mut self.members {
            member.backend = backend.clone();
        }
        self.backend = backend;
        self
    }
}

impl Regressor for OnlineBaggingRegressor {
    fn predict(&self, x: &[f64]) -> f64 {
        // only trained members vote (see [`super::vote`]): with every
        // Poisson draw possibly zero, a member can stay at the untrained
        // prior for a while
        fold_votes(self.members.iter().map(|m| (m.tree.predict(x), m.trained)))
    }

    fn learn_one(&mut self, x: &[f64], y: f64) {
        for member in &mut self.members {
            member.train_queued(x, y);
        }
        if self.members.iter().all(|m| m.tree.pending_attempts().is_empty()) {
            return; // hot path: attempts are due ~once per grace period
        }
        // one batched backend call resolves every member's due attempts
        let backend = self.backend.clone();
        let mut refs: Vec<&mut BagMember> = self.members.iter_mut().collect();
        <OnlineBaggingRegressor as ParallelEnsemble>::flush_members(
            &mut refs,
            backend.as_ref(),
        );
    }

    fn name(&self) -> String {
        format!("bag[{}x{}]", self.members.len(), self.observer_label)
    }

    fn n_elements(&self) -> usize {
        self.members.iter().map(|m| m.tree.total_elements()).sum()
    }
}

impl ParallelEnsemble for OnlineBaggingRegressor {
    type Member = BagMember;

    fn members_mut(&mut self) -> &mut [BagMember] {
        &mut self.members
    }

    fn learn_member(member: &mut BagMember, x: &[f64], y: f64) {
        member.learn(x, y);
    }

    fn train_member(member: &mut BagMember, x: &[f64], y: f64) {
        member.train_queued(x, y);
    }

    fn flush_members(members: &mut [&mut BagMember], backend: &dyn SplitBackend) -> bool {
        if members.iter().all(|m| m.tree.pending_attempts().is_empty()) {
            return false; // hot path: attempts are due ~once per grace period
        }
        let mut trees: Vec<&mut HoeffdingTreeRegressor> = Vec::with_capacity(members.len());
        for member in members.iter_mut() {
            trees.push(&mut member.tree);
        }
        flush_split_attempts(backend, &mut trees);
        true
    }

    fn split_backend(&self) -> Arc<dyn SplitBackend> {
        self.backend.clone()
    }

    fn member_predict(member: &BagMember, x: &[f64]) -> f64 {
        member.tree.predict(x)
    }

    fn member_trained(member: &BagMember) -> bool {
        member.trained
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::prequential::prequential;
    use crate::eval::MeanRegressor;
    use crate::observer::{factory, QuantizationObserver, RadiusPolicy};
    use crate::stream::{Friedman1, Stream};

    fn qo_factory() -> Box<dyn ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    #[test]
    fn bagging_beats_mean_baseline() {
        let n = 8000;
        let mut bag = OnlineBaggingRegressor::new(
            10,
            5,
            1.0,
            HtrOptions::default(),
            qo_factory(),
            42,
        );
        let mut mean = MeanRegressor::new();
        let r_bag = prequential(&mut bag, &mut Friedman1::new(5, 1.0), n, 0);
        let r_mean = prequential(&mut mean, &mut Friedman1::new(5, 1.0), n, 0);
        assert!(
            r_bag.metrics.rmse() < 0.85 * r_mean.metrics.rmse(),
            "bag rmse {} vs mean {}",
            r_bag.metrics.rmse(),
            r_mean.metrics.rmse()
        );
        assert!(bag.n_splits() >= 1);
    }

    #[test]
    fn members_diverge_via_poisson_weighting() {
        let mut bag = OnlineBaggingRegressor::new(
            10,
            3,
            1.0,
            HtrOptions::default(),
            qo_factory(),
            7,
        );
        let mut stream = Friedman1::new(9, 1.0);
        for _ in 0..5000 {
            let inst = stream.next_instance().unwrap();
            bag.learn_one(&inst.x, inst.y);
        }
        // different Poisson streams -> members see different effective
        // sample counts and (almost surely) differ in structure or output
        let probe = [0.5; 10];
        let preds: Vec<f64> = bag.members.iter().map(|m| m.tree.predict(&probe)).collect();
        assert!(
            preds.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-12),
            "members are identical: {preds:?}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let run = || {
            let mut bag = OnlineBaggingRegressor::new(
                10,
                4,
                6.0,
                HtrOptions::default(),
                qo_factory(),
                13,
            );
            let mut stream = Friedman1::new(3, 1.0);
            for _ in 0..2000 {
                let inst = stream.next_instance().unwrap();
                bag.learn_one(&inst.x, inst.y);
            }
            bag.predict(&[0.2; 10])
        };
        assert_eq!(run().to_bits(), run().to_bits());
    }

    #[test]
    fn name_reports_shape() {
        let bag =
            OnlineBaggingRegressor::new(2, 3, 1.0, HtrOptions::default(), qo_factory(), 1);
        assert_eq!(bag.name(), "bag[3xQO_s2]");
    }
}
