//! Oza–Russell online bagging (Oza & Russell 2001) over QO-backed
//! Hoeffding tree regressors.
//!
//! Batch bagging gives every member a bootstrap resample of the data;
//! online, each arriving instance is instead shown to member `m` a random
//! `k ~ Poisson(λ)` times (λ = 1 reproduces the bootstrap in expectation;
//! ARF uses λ = 6 to accelerate early growth). Every member owns an
//! independent PRNG, so training members in parallel
//! ([`crate::forest::parallel`]) is bit-for-bit identical to the
//! sequential loop.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::common::Rng;
use crate::eval::Regressor;
use crate::observer::{ArcFactory, ObserverFactory, ObserverSpec};
use crate::persist::codec::{field, jf64, parr, pbool, pf64, pstr, rng_from, rng_to_json};
use crate::runtime::backend::SplitBackend;
use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

use super::batch::flush_split_attempts;
use super::parallel::ParallelEnsemble;
use super::vote::{fold_votes, fold_votes_weighted};

/// Fading factor of the per-member recent-error estimate (see
/// [`super::arf`]'s identically tuned constant).
const VOTE_ERR_FADE: f64 = 0.99;

/// One bagged member: a tree plus its private Poisson weighting stream.
#[derive(Clone)]
pub struct BagMember {
    pub tree: HoeffdingTreeRegressor,
    rng: Rng,
    lambda: f64,
    backend: Arc<dyn SplitBackend>,
    /// Whether the tree has trained on ≥ 1 instance — every Poisson draw
    /// can be zero early on, and an untrained tree's prior-mean prediction
    /// must not enter the ensemble vote.
    trained: bool,
    /// Whether to maintain `vote_err` (costs one tree traversal per
    /// instance, so it is only paid when the weighted vote is on).
    track_err: bool,
    /// Recent prequential absolute error (EWMA) for the weighted vote.
    vote_err: f64,
    /// Whether `vote_err` absorbed its first sample yet.
    vote_seeded: bool,
}

impl BagMember {
    /// Train on one instance with Poisson(λ) importance (possibly zero
    /// times — the online analogue of being left out of the bootstrap),
    /// queueing due split attempts on the tree.
    pub(crate) fn train_queued(&mut self, x: &[f64], y: f64) {
        if self.track_err && self.trained {
            // prequential: error of the pre-update prediction
            let err = (y - self.tree.predict(x)).abs();
            self.vote_err = if self.vote_seeded {
                VOTE_ERR_FADE * self.vote_err + (1.0 - VOTE_ERR_FADE) * err
            } else {
                err
            };
            self.vote_seeded = true;
        }
        let k = self.rng.poisson(self.lambda);
        for _ in 0..k {
            self.tree.learn_one_deferred(x, y);
        }
        if k > 0 {
            self.trained = true;
        }
    }

    /// Self-contained member step (the parallel fitting path): train,
    /// then flush this member's queue through its backend. Bit-identical
    /// to the sequential forest round, which flushes all members at once.
    pub(crate) fn learn(&mut self, x: &[f64], y: f64) {
        self.train_queued(x, y);
        if !self.tree.pending_attempts().is_empty() {
            flush_split_attempts(self.backend.as_ref(), &mut [&mut self.tree]);
        }
    }

    /// Recent error for the weighted vote: `+∞` until the EWMA has seen
    /// its first sample (weight 0; see [`fold_votes_weighted`]).
    fn recent_err(&self) -> f64 {
        if self.vote_seeded {
            self.vote_err
        } else {
            f64::INFINITY
        }
    }
}

/// Online bagging ensemble of Hoeffding tree regressors.
#[derive(Clone)]
pub struct OnlineBaggingRegressor {
    members: Vec<BagMember>,
    observer_label: String,
    /// Shared split-query engine: one batched call per `learn_one` round.
    backend: Arc<dyn SplitBackend>,
    /// Fold the vote by inverse recent error ([`fold_votes_weighted`]).
    weighted_vote: bool,
    /// Instances absorbed since [`Self::mark_synced`] — runtime-only
    /// touched-state tracking for the serve/replication layer (not
    /// checkpointed).
    learns_since_sync: u64,
}

impl OnlineBaggingRegressor {
    /// Build `n_members` trees sharing one observer configuration. Member
    /// seeds (for both the Poisson stream and the tree's subspace draws)
    /// derive deterministically from `seed`.
    pub fn new(
        n_features: usize,
        n_members: usize,
        lambda: f64,
        tree_options: HtrOptions,
        factory: Box<dyn ObserverFactory>,
        seed: u64,
    ) -> OnlineBaggingRegressor {
        assert!(n_members >= 1, "need at least one member");
        assert!(lambda > 0.0, "lambda must be positive");
        let observer_label = factory.name();
        let shared: Arc<dyn ObserverFactory> = Arc::from(factory);
        let backend = tree_options.split_backend.build();
        let mut seeder = Rng::new(seed);
        let members = (0..n_members)
            .map(|i| {
                let mut rng = seeder.fork(i as u64);
                let opts = HtrOptions { seed: rng.next_u64(), ..tree_options };
                BagMember {
                    tree: HoeffdingTreeRegressor::new(
                        n_features,
                        opts,
                        Box::new(ArcFactory::new(shared.clone())),
                    ),
                    rng,
                    lambda,
                    backend: backend.clone(),
                    trained: false,
                    track_err: false,
                    vote_err: 0.0,
                    vote_seeded: false,
                }
            })
            .collect();
        OnlineBaggingRegressor {
            members,
            observer_label,
            backend,
            weighted_vote: false,
            learns_since_sync: 0,
        }
    }

    /// Instances absorbed since the last [`Self::mark_synced`]. The
    /// member-tree counters are folded in as a backstop, but they alone
    /// are NOT sufficient: member training mutates checkpointed state
    /// (PRNG words, error trackers) even when the Poisson draw trains no
    /// tree, so any path that trains members outside
    /// [`Regressor::learn_one`] must report its instances via
    /// [`Self::note_learns`].
    pub fn learns_since_sync(&self) -> u64 {
        self.members
            .iter()
            .map(|m| m.tree.learns_since_sync())
            .fold(self.learns_since_sync, u64::max)
    }

    /// Record `n` instances trained through an external member-training
    /// path (e.g. the sharded coordinator), which bypasses
    /// [`Regressor::learn_one`] and would otherwise leave the
    /// touched-state counter stale when every Poisson draw was zero.
    pub fn note_learns(&mut self, n: u64) {
        self.learns_since_sync += n;
    }

    /// Reset the touched-state counters after a snapshot/delta
    /// publication.
    pub fn mark_synced(&mut self) {
        self.learns_since_sync = 0;
        for member in &mut self.members {
            member.tree.mark_synced();
        }
    }

    /// Enable (or disable) the accuracy-weighted vote: members fold with
    /// weight inverse to their recent prequential error
    /// ([`fold_votes_weighted`]). Turning it on also starts the per-member
    /// error tracking (one extra tree traversal per member per instance).
    /// CLI: `qostream forest --weighted-vote`.
    pub fn with_weighted_vote(mut self, weighted: bool) -> OnlineBaggingRegressor {
        self.weighted_vote = weighted;
        for member in &mut self.members {
            member.track_err = weighted;
        }
        self
    }

    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    /// Input dimensionality the ensemble was built for.
    pub fn n_features(&self) -> usize {
        self.members.first().map(|m| m.tree.n_features()).unwrap_or(0)
    }

    /// Total splits across members (growth indicator).
    pub fn n_splits(&self) -> usize {
        self.members.iter().map(|m| m.tree.n_splits()).sum()
    }

    /// Resident heap footprint in bytes across all member trees — the
    /// byte-level companion of [`Regressor::n_elements`].
    pub fn mem_bytes(&self) -> usize {
        std::mem::size_of::<OnlineBaggingRegressor>()
            + self
                .members
                .iter()
                .map(|m| std::mem::size_of::<BagMember>() + m.tree.mem_bytes())
                .sum::<usize>()
    }

    /// Memory-governance step (a) ([`crate::govern`]): compact QO slot
    /// tables on every member tree
    /// ([`HoeffdingTreeRegressor::compact_observers`]). Returns how many
    /// observers shrank.
    pub fn compact_observers(&mut self, target_slots: usize) -> usize {
        self.members
            .iter_mut()
            .map(|m| m.tree.compact_observers(target_slots))
            .sum()
    }

    /// Memory-governance step (b) ([`crate::govern`]): deactivate
    /// observers on the `per_tree` coldest leaves of every member tree
    /// ([`HoeffdingTreeRegressor::evict_coldest`]). Returns the total
    /// leaves evicted.
    pub fn evict_coldest(&mut self, per_tree: usize) -> usize {
        self.members.iter_mut().map(|m| m.tree.evict_coldest(per_tree)).sum()
    }

    /// Leaves still holding observers across all member trees.
    pub fn n_active_leaves(&self) -> usize {
        self.members.iter().map(|m| m.tree.n_active_leaves()).sum()
    }

    /// Memory-governance step (c) ([`crate::govern`]): drop the member
    /// with the worst recent prequential error ([`BagMember::recent_err`];
    /// without `--weighted-vote` no errors are tracked, every member
    /// ranks `+∞` and the tie rule prunes the last member). Ties prune
    /// the later member; the last member always survives. Returns the
    /// pruned member's index, or `None` when only one remains.
    pub fn prune_worst(&mut self) -> Option<usize> {
        if self.members.len() <= 1 {
            return None;
        }
        let mut worst = 0usize;
        for (i, m) in self.members.iter().enumerate() {
            if m.recent_err() > self.members[worst].recent_err()
                || (i > worst
                    && m.recent_err() == self.members[worst].recent_err())
            {
                worst = i;
            }
        }
        self.members.remove(worst);
        Some(worst)
    }

    /// Replace the shared split-query engine (e.g. an instrumented backend
    /// in tests); every member's flush handle is updated too.
    pub fn with_split_backend(
        mut self,
        backend: Arc<dyn SplitBackend>,
    ) -> OnlineBaggingRegressor {
        for member in &mut self.members {
            member.backend = backend.clone();
        }
        self.backend = backend;
        self
    }

    /// Checkpoint encoding ([`crate::persist`]): every member's tree, PRNG
    /// and vote state (λ and the observer travel at the top level — they
    /// are shared configuration).
    pub fn to_json(&self) -> Result<Json> {
        let spec = ObserverSpec::from_label(&self.observer_label).ok_or_else(|| {
            anyhow!(
                "observer factory {:?} is not checkpointable",
                self.observer_label
            )
        })?;
        let first = self
            .members
            .first()
            .ok_or_else(|| anyhow!("ensemble has no members"))?;
        let mut members = Vec::with_capacity(self.members.len());
        for m in &self.members {
            let mut o = Json::obj();
            o.set("tree", m.tree.to_json()?)
                .set("rng", rng_to_json(&m.rng))
                .set("trained", m.trained)
                .set("vote_err", jf64(m.vote_err))
                .set("vote_seeded", m.vote_seeded);
            members.push(o);
        }
        let mut o = Json::obj();
        o.set("observer", spec.label())
            .set("lambda", jf64(first.lambda))
            .set("weighted_vote", self.weighted_vote)
            .set("members", Json::Arr(members));
        Ok(o)
    }

    /// Decode an ensemble written by [`OnlineBaggingRegressor::to_json`].
    pub fn from_json(j: &Json) -> Result<OnlineBaggingRegressor> {
        let label = pstr(field(j, "observer")?, "observer")?;
        if ObserverSpec::from_label(label).is_none() {
            return Err(anyhow!("unknown observer label {label:?}"));
        }
        let lambda = pf64(field(j, "lambda")?, "lambda")?;
        let weighted_vote = pbool(field(j, "weighted_vote")?, "weighted_vote")?;
        let mut members = Vec::new();
        let mut backend: Option<Arc<dyn SplitBackend>> = None;
        for m in parr(field(j, "members")?, "members")? {
            let tree = HoeffdingTreeRegressor::from_json(field(m, "tree")?)?;
            let member_backend = backend
                .get_or_insert_with(|| tree.options().split_backend.build())
                .clone();
            members.push(BagMember {
                tree,
                rng: rng_from(field(m, "rng")?, "rng")?,
                lambda,
                backend: member_backend,
                trained: pbool(field(m, "trained")?, "trained")?,
                track_err: weighted_vote,
                vote_err: pf64(field(m, "vote_err")?, "vote_err")?,
                vote_seeded: pbool(field(m, "vote_seeded")?, "vote_seeded")?,
            });
        }
        if members.is_empty() {
            return Err(anyhow!("bagging checkpoint has no members"));
        }
        Ok(OnlineBaggingRegressor {
            members,
            observer_label: label.to_string(),
            backend: backend.expect("members is non-empty"),
            weighted_vote,
            learns_since_sync: 0,
        })
    }
}

impl Regressor for OnlineBaggingRegressor {
    fn predict(&self, x: &[f64]) -> f64 {
        // only trained members vote (see [`super::vote`]): with every
        // Poisson draw possibly zero, a member can stay at the untrained
        // prior for a while
        if self.weighted_vote {
            fold_votes_weighted(
                self.members
                    .iter()
                    .map(|m| (m.tree.predict(x), m.trained, m.recent_err())),
            )
        } else {
            fold_votes(self.members.iter().map(|m| (m.tree.predict(x), m.trained)))
        }
    }

    fn learn_one(&mut self, x: &[f64], y: f64) {
        self.learns_since_sync += 1;
        for member in &mut self.members {
            member.train_queued(x, y);
        }
        if self.members.iter().all(|m| m.tree.pending_attempts().is_empty()) {
            return; // hot path: attempts are due ~once per grace period
        }
        // one batched backend call resolves every member's due attempts
        let backend = self.backend.clone();
        let mut refs: Vec<&mut BagMember> = self.members.iter_mut().collect();
        <OnlineBaggingRegressor as ParallelEnsemble>::flush_members(
            &mut refs,
            backend.as_ref(),
        );
    }

    fn name(&self) -> String {
        format!("bag[{}x{}]", self.members.len(), self.observer_label)
    }

    fn n_elements(&self) -> usize {
        self.members.iter().map(|m| m.tree.total_elements()).sum()
    }
}

impl ParallelEnsemble for OnlineBaggingRegressor {
    type Member = BagMember;

    fn members_mut(&mut self) -> &mut [BagMember] {
        &mut self.members
    }

    fn learn_member(member: &mut BagMember, x: &[f64], y: f64) {
        member.learn(x, y);
    }

    fn train_member(member: &mut BagMember, x: &[f64], y: f64) {
        member.train_queued(x, y);
    }

    fn flush_members(members: &mut [&mut BagMember], backend: &dyn SplitBackend) -> bool {
        if members.iter().all(|m| m.tree.pending_attempts().is_empty()) {
            return false; // hot path: attempts are due ~once per grace period
        }
        let mut trees: Vec<&mut HoeffdingTreeRegressor> = Vec::with_capacity(members.len());
        for member in members.iter_mut() {
            trees.push(&mut member.tree);
        }
        flush_split_attempts(backend, &mut trees);
        true
    }

    fn split_backend(&self) -> Arc<dyn SplitBackend> {
        self.backend.clone()
    }

    fn member_predict(member: &BagMember, x: &[f64]) -> f64 {
        member.tree.predict(x)
    }

    fn member_trained(member: &BagMember) -> bool {
        member.trained
    }

    fn member_recent_err(member: &BagMember) -> f64 {
        member.recent_err()
    }

    fn weighted_vote(&self) -> bool {
        self.weighted_vote
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::prequential::prequential;
    use crate::eval::MeanRegressor;
    use crate::observer::{factory, QuantizationObserver, RadiusPolicy};
    use crate::stream::{Friedman1, Stream};

    fn qo_factory() -> Box<dyn ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    #[test]
    fn bagging_beats_mean_baseline() {
        let n = 8000;
        let mut bag = OnlineBaggingRegressor::new(
            10,
            5,
            1.0,
            HtrOptions::default(),
            qo_factory(),
            42,
        );
        let mut mean = MeanRegressor::new();
        let r_bag = prequential(&mut bag, &mut Friedman1::new(5, 1.0), n, 0);
        let r_mean = prequential(&mut mean, &mut Friedman1::new(5, 1.0), n, 0);
        assert!(
            r_bag.metrics.rmse() < 0.85 * r_mean.metrics.rmse(),
            "bag rmse {} vs mean {}",
            r_bag.metrics.rmse(),
            r_mean.metrics.rmse()
        );
        assert!(bag.n_splits() >= 1);
    }

    #[test]
    fn members_diverge_via_poisson_weighting() {
        let mut bag = OnlineBaggingRegressor::new(
            10,
            3,
            1.0,
            HtrOptions::default(),
            qo_factory(),
            7,
        );
        let mut stream = Friedman1::new(9, 1.0);
        for _ in 0..5000 {
            let inst = stream.next_instance().unwrap();
            bag.learn_one(&inst.x, inst.y);
        }
        // different Poisson streams -> members see different effective
        // sample counts and (almost surely) differ in structure or output
        let probe = [0.5; 10];
        let preds: Vec<f64> = bag.members.iter().map(|m| m.tree.predict(&probe)).collect();
        assert!(
            preds.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-12),
            "members are identical: {preds:?}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let run = || {
            let mut bag = OnlineBaggingRegressor::new(
                10,
                4,
                6.0,
                HtrOptions::default(),
                qo_factory(),
                13,
            );
            let mut stream = Friedman1::new(3, 1.0);
            for _ in 0..2000 {
                let inst = stream.next_instance().unwrap();
                bag.learn_one(&inst.x, inst.y);
            }
            bag.predict(&[0.2; 10])
        };
        assert_eq!(run().to_bits(), run().to_bits());
    }

    #[test]
    fn governance_walkers_cover_members_and_prune_keeps_one() {
        let mut bag = OnlineBaggingRegressor::new(
            10,
            3,
            1.0,
            HtrOptions::default(),
            factory("QO_0.01", || {
                Box::new(QuantizationObserver::new(RadiusPolicy::fixed(0.01)))
            }),
            11,
        );
        let mut stream = Friedman1::new(5, 1.0);
        for _ in 0..4000 {
            let inst = stream.next_instance().unwrap();
            bag.learn_one(&inst.x, inst.y);
        }
        let probe = [0.4; 10];
        let before_mem = bag.mem_bytes();
        let before_pred = bag.predict(&probe);
        let compacted = bag.compact_observers(8);
        assert!(compacted > 0, "expected dense QO tables to compact");
        assert!(bag.mem_bytes() < before_mem, "compaction must shrink mem");
        assert_eq!(
            bag.predict(&probe).to_bits(),
            before_pred.to_bits(),
            "compaction must not touch predictions"
        );

        let active = bag.n_active_leaves();
        assert!(active >= bag.n_members());
        let evicted = bag.evict_coldest(1);
        assert_eq!(evicted, bag.n_members(), "one leaf per member tree");
        assert!(bag.n_active_leaves() < active);

        // Without weighted voting every member ranks +inf, so ties prune
        // the later member until one remains.
        assert_eq!(bag.prune_worst(), Some(2));
        assert_eq!(bag.prune_worst(), Some(1));
        assert_eq!(bag.n_members(), 1);
        assert_eq!(bag.prune_worst(), None, "last member survives");
        // The survivor still round-trips.
        let j = bag.to_json().unwrap();
        let back = OnlineBaggingRegressor::from_json(&j).unwrap();
        assert_eq!(back.predict(&probe).to_bits(), bag.predict(&probe).to_bits());
    }

    #[test]
    fn name_reports_shape() {
        let bag =
            OnlineBaggingRegressor::new(2, 3, 1.0, HtrOptions::default(), qo_factory(), 1);
        assert_eq!(bag.name(), "bag[3xQO_s2]");
    }

    #[test]
    fn json_roundtrip_predicts_and_trains_identically() {
        let mut bag = OnlineBaggingRegressor::new(
            10,
            3,
            2.0,
            HtrOptions::default(),
            qo_factory(),
            23,
        );
        let mut stream = Friedman1::new(11, 1.0);
        for _ in 0..2500 {
            let inst = stream.next_instance().unwrap();
            bag.learn_one(&inst.x, inst.y);
        }
        let text = bag.to_json().unwrap().to_compact();
        let mut back = OnlineBaggingRegressor::from_json(
            &crate::common::json::Json::parse(&text).unwrap(),
        )
        .unwrap();
        assert_eq!(back.name(), bag.name());
        assert_eq!(back.n_splits(), bag.n_splits());
        let probe = [0.4; 10];
        assert_eq!(bag.predict(&probe).to_bits(), back.predict(&probe).to_bits());
        for _ in 0..2500 {
            let inst = stream.next_instance().unwrap();
            bag.learn_one(&inst.x, inst.y);
            back.learn_one(&inst.x, inst.y);
        }
        assert_eq!(back.n_splits(), bag.n_splits());
        assert_eq!(bag.predict(&probe).to_bits(), back.predict(&probe).to_bits());
    }

    #[test]
    fn weighted_vote_beats_flat_mean_after_concept_swap() {
        // Concept A: Friedman #1. Concept B: its reflection y ↦ 20 − y
        // (a drastic swap, so a stale member is *systematically* wrong).
        // Members 1 and 2 keep adapting on B while member 0 stops
        // training at the swap — the situation accuracy weighting exists
        // for: the flat mean keeps averaging the stale member in, the
        // weighted vote suppresses it by its inverse recent error.
        let mut bag = OnlineBaggingRegressor::new(
            10,
            3,
            1.0,
            HtrOptions::default(),
            qo_factory(),
            19,
        )
        .with_weighted_vote(true);
        let mut concept_a = Friedman1::new(5, 1.0);
        for _ in 0..4000 {
            let inst = concept_a.next_instance().unwrap();
            bag.learn_one(&inst.x, inst.y);
        }
        let mut concept_b = Friedman1::new(6, 1.0);
        for _ in 0..6000 {
            let inst = concept_b.next_instance().unwrap();
            let y = 20.0 - inst.y;
            for m in 1..3 {
                bag.members[m].learn(&inst.x, y);
            }
        }
        // recent errors exactly as the prequential monitor would settle
        // on them: each member's MAE on held-out concept-B instances
        let mut probe = Friedman1::new(7, 0.0);
        let probes: Vec<(Vec<f64>, f64)> = (0..300)
            .map(|_| {
                let inst = probe.next_instance().unwrap();
                (inst.x, 20.0 - inst.y)
            })
            .collect();
        for m in 0..3 {
            let mae = probes
                .iter()
                .map(|(x, y)| (y - bag.members[m].tree.predict(x)).abs())
                .sum::<f64>()
                / probes.len() as f64;
            bag.members[m].vote_err = mae;
            bag.members[m].vote_seeded = true;
        }
        assert!(
            bag.members[0].vote_err > bag.members[1].vote_err
                && bag.members[0].vote_err > bag.members[2].vote_err,
            "the member left on concept A must be the stale one: {:?}",
            [
                bag.members[0].vote_err,
                bag.members[1].vote_err,
                bag.members[2].vote_err
            ]
        );
        let rmse = |bag: &OnlineBaggingRegressor| {
            (probes
                .iter()
                .map(|(x, y)| {
                    let e = y - bag.predict(x);
                    e * e
                })
                .sum::<f64>()
                / probes.len() as f64)
                .sqrt()
        };
        let weighted = rmse(&bag);
        bag.weighted_vote = false;
        let flat = rmse(&bag);
        assert!(
            weighted < flat,
            "weighted vote must beat the flat mean after the swap: \
             weighted {weighted} vs flat {flat}"
        );
    }
}
