//! Ensemble vote folding.
//!
//! A freshly (re)started member — a post-drift-swap ARF tree, or a bagged
//! member whose Poisson draws have all been zero so far — predicts the
//! untrained prior mean, and averaging it into the ensemble vote drags the
//! prediction toward that prior for no reason. [`fold_votes`] is the one
//! shared vote: the mean over *trained* members, falling back to the flat
//! mean of every member's (prior) prediction only when no member has
//! trained yet.
//!
//! Both sequential `predict` implementations ([`super::ArfRegressor`],
//! [`super::OnlineBaggingRegressor`]) and the sharded-forest leader
//! ([`crate::coordinator::forest`]) fold through this function **in global
//! member order**, which is what makes the leader-merged distributed vote
//! bit-for-bit identical to the sequential ensemble: IEEE addition is not
//! associative, so shipping pre-reduced per-shard Σs would reassociate the
//! sum — instead shards ship per-member votes and the leader replays the
//! exact sequential fold.

/// Fold `(prediction, trained)` votes, in member order, into the ensemble
/// prediction (see module docs). Returns 0.0 for an empty vote.
pub fn fold_votes<I: Iterator<Item = (f64, bool)>>(votes: I) -> f64 {
    let (mut sum_all, mut n_all) = (0.0f64, 0usize);
    let (mut sum_trained, mut n_trained) = (0.0f64, 0usize);
    for (pred, trained) in votes {
        sum_all += pred;
        n_all += 1;
        if trained {
            sum_trained += pred;
            n_trained += 1;
        }
    }
    if n_trained > 0 {
        sum_trained / n_trained as f64
    } else if n_all > 0 {
        sum_all / n_all as f64
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trained_members_only() {
        let v = fold_votes([(10.0, true), (0.0, false), (14.0, true)].into_iter());
        assert_eq!(v, 12.0);
    }

    #[test]
    fn all_untrained_falls_back_to_flat_mean() {
        let v = fold_votes([(1.0, false), (2.0, false), (3.0, false)].into_iter());
        assert_eq!(v, 2.0);
    }

    #[test]
    fn empty_vote_is_zero() {
        assert_eq!(fold_votes(std::iter::empty()), 0.0);
    }

    #[test]
    fn single_trained_member_wins_outright() {
        let v = fold_votes([(0.0, false), (7.5, true), (0.0, false)].into_iter());
        assert_eq!(v, 7.5);
    }
}
