//! Ensemble vote folding.
//!
//! A freshly (re)started member — a post-drift-swap ARF tree, or a bagged
//! member whose Poisson draws have all been zero so far — predicts the
//! untrained prior mean, and averaging it into the ensemble vote drags the
//! prediction toward that prior for no reason. [`fold_votes`] is the one
//! shared vote: the mean over *trained* members, falling back to the flat
//! mean of every member's (prior) prediction only when no member has
//! trained yet.
//!
//! Both sequential `predict` implementations ([`super::ArfRegressor`],
//! [`super::OnlineBaggingRegressor`]) and the sharded-forest leader
//! ([`crate::coordinator::forest`]) fold through this function **in global
//! member order**, which is what makes the leader-merged distributed vote
//! bit-for-bit identical to the sequential ensemble: IEEE addition is not
//! associative, so shipping pre-reduced per-shard Σs would reassociate the
//! sum — instead shards ship per-member votes and the leader replays the
//! exact sequential fold.

/// Floor added to a member's recent error before inversion, so a member
/// with a (transiently) zero error estimate cannot swallow the whole vote.
const WEIGHT_ERR_FLOOR: f64 = 1e-6;

/// Accuracy-weighted fold: each *trained* member votes with weight
/// `1 / (ε + recent_err)` — its inverse recent prequential absolute error
/// — so members still fitting the current concept count for more than
/// members whose error exploded after a drift. Folds **in member order**
/// (same reasoning as [`fold_votes`]: the sharded leader replays this
/// exact fold, and IEEE addition is not associative). Falls back to the
/// flat mean of every member's prediction when no weight mass exists —
/// no member trained, or every trained member still lacks an error
/// estimate. A member with no estimate yet must pass `recent_err = +∞`
/// (weight exactly 0.0), NOT 0.0: a zero error would hand a barely
/// trained tree the maximal weight and let it swallow the vote.
pub fn fold_votes_weighted<I: Iterator<Item = (f64, bool, f64)>>(votes: I) -> f64 {
    let (mut sum_all, mut n_all) = (0.0f64, 0usize);
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (pred, trained, recent_err) in votes {
        sum_all += pred;
        n_all += 1;
        if trained {
            let w = 1.0 / (WEIGHT_ERR_FLOOR + recent_err.max(0.0));
            num += w * pred;
            den += w;
        }
    }
    if den > 0.0 {
        num / den
    } else if n_all > 0 {
        sum_all / n_all as f64
    } else {
        0.0
    }
}

/// Fold `(prediction, trained)` votes, in member order, into the ensemble
/// prediction (see module docs). Returns 0.0 for an empty vote.
pub fn fold_votes<I: Iterator<Item = (f64, bool)>>(votes: I) -> f64 {
    let (mut sum_all, mut n_all) = (0.0f64, 0usize);
    let (mut sum_trained, mut n_trained) = (0.0f64, 0usize);
    for (pred, trained) in votes {
        sum_all += pred;
        n_all += 1;
        if trained {
            sum_trained += pred;
            n_trained += 1;
        }
    }
    if n_trained > 0 {
        sum_trained / n_trained as f64
    } else if n_all > 0 {
        sum_all / n_all as f64
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trained_members_only() {
        let v = fold_votes([(10.0, true), (0.0, false), (14.0, true)].into_iter());
        assert_eq!(v, 12.0);
    }

    #[test]
    fn all_untrained_falls_back_to_flat_mean() {
        let v = fold_votes([(1.0, false), (2.0, false), (3.0, false)].into_iter());
        assert_eq!(v, 2.0);
    }

    #[test]
    fn empty_vote_is_zero() {
        assert_eq!(fold_votes(std::iter::empty()), 0.0);
    }

    #[test]
    fn single_trained_member_wins_outright() {
        let v = fold_votes([(0.0, false), (7.5, true), (0.0, false)].into_iter());
        assert_eq!(v, 7.5);
    }

    #[test]
    fn weighted_vote_downweights_the_inaccurate_member() {
        // truth 10.0; one stale member predicts 0.0 with a large recent
        // error: the weighted vote must land far closer to the truth than
        // the flat mean does
        let votes = [(10.0, true, 0.1), (10.2, true, 0.1), (0.0, true, 5.0)];
        let weighted = fold_votes_weighted(votes.into_iter());
        let flat = fold_votes(votes.into_iter().map(|(p, t, _)| (p, t)));
        assert!((weighted - 10.0).abs() < (flat - 10.0).abs());
        assert!((weighted - 10.0).abs() < 0.5, "weighted={weighted}");
        assert!((flat - 10.0).abs() > 3.0, "flat={flat}");
    }

    #[test]
    fn weighted_vote_equal_errors_equals_flat_mean() {
        let votes = [(1.0, true, 0.5), (2.0, true, 0.5), (6.0, true, 0.5)];
        let weighted = fold_votes_weighted(votes.into_iter());
        assert!((weighted - 3.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_vote_untrained_fallback_and_empty() {
        let v = fold_votes_weighted([(1.0, false, 0.0), (3.0, false, 9.0)].into_iter());
        assert_eq!(v, 2.0);
        assert_eq!(fold_votes_weighted(std::iter::empty()), 0.0);
    }

    #[test]
    fn weighted_vote_zero_error_does_not_divide_by_zero() {
        let v = fold_votes_weighted([(4.0, true, 0.0), (8.0, true, 0.0)].into_iter());
        assert!(v.is_finite());
        assert!((v - 6.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_vote_infinite_error_means_zero_weight() {
        // the no-estimate-yet sentinel: the member is excluded, it does
        // not dominate
        let v = fold_votes_weighted(
            [(100.0, true, f64::INFINITY), (2.0, true, 0.5)].into_iter(),
        );
        assert!((v - 2.0).abs() < 1e-9, "v={v}");
        // all-sentinel trained members: fall back to the flat mean
        let v = fold_votes_weighted(
            [(1.0, true, f64::INFINITY), (3.0, true, f64::INFINITY)].into_iter(),
        );
        assert!((v - 2.0).abs() < 1e-9, "v={v}");
    }
}
