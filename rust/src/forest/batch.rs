//! Cross-member batched split-attempt flushing.
//!
//! Forest members train in deferred-attempt mode
//! ([`HoeffdingTreeRegressor::learn_one_deferred`]): due split attempts
//! queue on each tree instead of being evaluated inline. Once every
//! member has consumed the instance, [`flush_split_attempts`] gathers all
//! queued leaves across all member (and background) trees and answers
//! every feature of every leaf through **one** [`SplitBackend`] call —
//! the forest-scale amortization the ROADMAP's "one PJRT call per forest
//! tick" goal needs, and a single flat pass for the native batch backend.
//!
//! Determinism: which leaves are due is a pure function of per-member
//! state (each leaf's observed weight against its grace period), never of
//! thread timing, and backend evaluation is independent per query — so a
//! member flushed alone (the [`super::parallel::fit_parallel`] worker
//! path) resolves exactly as it does inside the forest-wide batch, and
//! `fit_parallel` stays bit-for-bit identical to sequential training.

use crate::observer::SplitSuggestion;
use crate::runtime::backend::{SplitBackend, SplitQuery};
use crate::tree::HoeffdingTreeRegressor;

/// Drain every tree's deferred-attempt queue and resolve all of them
/// through a single `backend.best_splits` call.
pub fn flush_split_attempts(
    backend: &dyn SplitBackend,
    trees: &mut [&mut HoeffdingTreeRegressor],
) {
    // Phase 1 (mutable): drain the queues into (tree, leaf) jobs.
    let mut jobs: Vec<(usize, u32)> = Vec::new();
    for (ti, tree) in trees.iter_mut().enumerate() {
        for leaf_idx in tree.take_pending() {
            jobs.push((ti, leaf_idx));
        }
    }
    if jobs.is_empty() {
        return;
    }

    // Phase 2 (shared): flatten every job's observers into one query list.
    let mut queries: Vec<SplitQuery<'_>> = Vec::new();
    let mut segments: Vec<(usize, usize)> = Vec::with_capacity(jobs.len());
    for &(ti, leaf_idx) in &jobs {
        let tree: &HoeffdingTreeRegressor = &*trees[ti];
        let criterion = tree.criterion();
        let start = queries.len();
        for ao in tree.leaf_observers(leaf_idx) {
            queries.push(SplitQuery { observer: ao.as_ref(), criterion });
        }
        segments.push((start, queries.len()));
    }

    // Phase 3: one backend call for the whole forest round.
    let started = crate::obs::m().map(|_| std::time::Instant::now());
    let results: Vec<Option<SplitSuggestion>> = backend.best_splits(&queries);
    if let Some(m) = crate::obs::m() {
        m.backend_batches.inc();
        m.backend_batch_size.record(queries.len() as u64);
        if let Some(t) = started {
            m.backend_latency_ns.record(t.elapsed().as_nanos() as u64);
        }
    }
    drop(queries);

    // Phase 4 (mutable): hand each job its result segment.
    for (&(ti, leaf_idx), &(start, end)) in jobs.iter().zip(&segments) {
        trees[ti].resolve_attempt(leaf_idx, &results[start..end]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::Rng;
    use crate::eval::Regressor;
    use crate::observer::{factory, ObserverFactory, QuantizationObserver, RadiusPolicy};
    use crate::runtime::backend::{NativeBatchBackend, PerObserverBackend};
    use crate::tree::HtrOptions;

    fn qo_factory() -> Box<dyn ObserverFactory> {
        factory("QO_s2", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::std_fraction(2.0)))
        })
    }

    fn tree() -> HoeffdingTreeRegressor {
        HoeffdingTreeRegressor::new(2, HtrOptions::default(), qo_factory())
    }

    #[test]
    fn batched_flush_equals_inline_attempts() {
        // two deferred trees flushed through ONE cross-tree backend call
        // per instance must match two inline trees exactly
        let (mut inline_a, mut inline_b) = (tree(), tree());
        let (mut def_a, mut def_b) = (tree(), tree());
        let backend = NativeBatchBackend;
        let mut rng = Rng::new(1234);
        for _ in 0..6000 {
            let xa = [rng.f64(), rng.f64()];
            let xb = [rng.f64(), rng.f64()];
            let (ya, yb) = (
                if xa[0] <= 0.4 { 0.0 } else { 2.0 },
                if xb[1] <= 0.6 { 1.0 } else { -1.0 },
            );
            inline_a.learn_one(&xa, ya);
            inline_b.learn_one(&xb, yb);
            def_a.learn_one_deferred(&xa, ya);
            def_b.learn_one_deferred(&xb, yb);
            flush_split_attempts(&backend, &mut [&mut def_a, &mut def_b]);
        }
        assert!(inline_a.n_splits() + inline_b.n_splits() >= 2, "trees never grew");
        assert_eq!(inline_a.n_splits(), def_a.n_splits());
        assert_eq!(inline_b.n_splits(), def_b.n_splits());
        for _ in 0..50 {
            let probe = [rng.f64(), rng.f64()];
            assert_eq!(
                inline_a.predict(&probe).to_bits(),
                def_a.predict(&probe).to_bits()
            );
            assert_eq!(
                inline_b.predict(&probe).to_bits(),
                def_b.predict(&probe).to_bits()
            );
        }
    }

    #[test]
    fn backends_agree_through_the_batched_flush() {
        let run = |use_batch: bool| {
            let mut t = tree();
            let mut rng = Rng::new(77);
            for _ in 0..5000 {
                let x = [rng.f64(), rng.f64()];
                let y = if x[0] <= 0.5 { -3.0 } else { 3.0 };
                t.learn_one_deferred(&x, y);
                if use_batch {
                    flush_split_attempts(&NativeBatchBackend, &mut [&mut t]);
                } else {
                    flush_split_attempts(&PerObserverBackend, &mut [&mut t]);
                }
            }
            t
        };
        let a = run(true);
        let b = run(false);
        assert_eq!(a.n_splits(), b.n_splits());
        assert!(a.n_splits() >= 1);
        let mut rng = Rng::new(78);
        for _ in 0..50 {
            let probe = [rng.f64(), rng.f64()];
            assert_eq!(a.predict(&probe).to_bits(), b.predict(&probe).to_bits());
        }
    }

    #[test]
    fn empty_queues_are_a_noop() {
        let mut a = tree();
        flush_split_attempts(&NativeBatchBackend, &mut [&mut a]);
        assert_eq!(a.n_splits(), 0);
        flush_split_attempts(&NativeBatchBackend, &mut []);
    }
}
