//! ADWIN — ADaptive WINdowing drift detection (Bifet & Gavaldà 2007).
//!
//! Maintains a variable-length window over a real-valued signal (here: a
//! tree's prequential absolute error) as an exponential histogram: rows of
//! at most [`MAX_BUCKETS`] buckets, where a row-`i` bucket summarizes 2^i
//! observations. Whenever two adjacent sub-windows have means that differ
//! by more than a δ-calibrated bound, the older sub-window is dropped —
//! the window adapts itself to the most recent concept.
//!
//! The buckets are the paper's own Sec. 3 [`VarStats`] estimators: row
//! compaction is the Chan **merge** and window shrinking is the paper's
//! **subtraction** extension, so the detector inherits the same numerical
//! robustness the observers do (no catastrophic cancellation under large
//! error offsets).
//!
//! The cut bound follows the original paper's normal-approximation form:
//!
//! ```text
//! eps_cut = sqrt(2 m σ²_W ln(2/δ')) + (2/3) m ln(2/δ'),   m = 1/n0 + 1/n1
//! ```
//!
//! with δ' = δ / W (union bound over the W possible cut positions).

use anyhow::{anyhow, Result};

use crate::common::json::Json;
use crate::persist::codec::{
    field, jf64, jusize, parr, pbool, pf64, pusize, varstats_from, varstats_to_json,
};
use crate::stats::VarStats;

/// Maximum buckets kept per exponential-histogram row.
const MAX_BUCKETS: usize = 5;
/// Cut checks run every `CLOCK` observations (amortizes the O(log W) scan).
const CLOCK: u32 = 32;
/// Each side of a candidate cut must hold at least this much weight.
const MIN_SIDE: f64 = 5.0;
/// No cut checks until the window holds at least this many observations.
const MIN_WINDOW: f64 = 16.0;

/// ADWIN change detector over a streaming real-valued signal.
#[derive(Clone, Debug)]
pub struct Adwin {
    delta: f64,
    /// `rows[i]` holds buckets of 2^i observations, oldest first; higher
    /// rows are older. Global order oldest→newest is: rows from last to
    /// first, each row front to back.
    rows: Vec<Vec<VarStats>>,
    total: VarStats,
    tick: u32,
    n_detections: usize,
    /// Direction of the last detection: `true` when the kept (recent)
    /// window had a HIGHER mean than the dropped prefix. Consumers
    /// monitoring an error signal use this to distinguish degradation
    /// (rising error → real drift) from improvement (falling error while
    /// a model converges — a change ADWIN rightly adapts to, but not a
    /// reason to discard the model).
    last_shrink_rise: bool,
}

impl Adwin {
    /// `delta` is the false-alarm confidence (smaller = more conservative;
    /// ARF convention: 0.01 for warnings, 0.001 for drifts).
    pub fn new(delta: f64) -> Adwin {
        assert!(delta > 0.0 && delta < 1.0, "delta must be in (0, 1)");
        Adwin {
            delta,
            rows: vec![Vec::new()],
            total: VarStats::new(),
            tick: 0,
            n_detections: 0,
            last_shrink_rise: false,
        }
    }

    /// Feed one observation; returns `true` when a distribution change was
    /// detected (the window just dropped its stale prefix).
    pub fn update(&mut self, value: f64) -> bool {
        if !value.is_finite() {
            return false;
        }
        self.total.update(value, 1.0);
        self.rows[0].push(VarStats::from_one(value, 1.0));
        self.compress();
        self.tick += 1;
        if self.tick >= CLOCK {
            self.tick = 0;
            self.shrink()
        } else {
            false
        }
    }

    /// Mean of the current window.
    pub fn mean(&self) -> f64 {
        self.total.mean
    }

    /// Sample variance of the current window.
    pub fn variance(&self) -> f64 {
        self.total.variance()
    }

    /// Observations currently in the window.
    pub fn width(&self) -> usize {
        self.total.n.round() as usize
    }

    /// Number of detected changes since construction / last reset.
    pub fn n_detections(&self) -> usize {
        self.n_detections
    }

    /// Whether the most recent detection saw the signal RISE (recent mean
    /// above the dropped prefix's mean). Meaningful right after
    /// [`Adwin::update`] returns `true`.
    pub fn rising(&self) -> bool {
        self.last_shrink_rise
    }

    /// Forget everything (fresh detector, same delta).
    pub fn reset(&mut self) {
        self.rows = vec![Vec::new()];
        self.total = VarStats::new();
        self.tick = 0;
        self.n_detections = 0;
        self.last_shrink_rise = false;
    }

    /// Checkpoint encoding ([`crate::persist`]): the full exponential
    /// histogram plus the clock phase, so a restored detector fires at the
    /// exact same instants the live one would have.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("delta", jf64(self.delta))
            .set(
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|row| Json::Arr(row.iter().map(varstats_to_json).collect()))
                        .collect(),
                ),
            )
            .set("total", varstats_to_json(&self.total))
            .set("tick", jusize(self.tick as usize))
            .set("n_detections", jusize(self.n_detections))
            .set("last_shrink_rise", self.last_shrink_rise);
        o
    }

    /// Decode a detector written by [`Adwin::to_json`].
    pub fn from_json(j: &Json) -> Result<Adwin> {
        let delta = pf64(field(j, "delta")?, "delta")?;
        if !(delta > 0.0 && delta < 1.0) {
            return Err(anyhow!("adwin delta {delta} out of (0, 1)"));
        }
        let mut rows = Vec::new();
        for row in parr(field(j, "rows")?, "rows")? {
            let mut buckets = Vec::new();
            for bucket in parr(row, "rows")? {
                buckets.push(varstats_from(bucket, "rows")?);
            }
            rows.push(buckets);
        }
        if rows.is_empty() {
            rows.push(Vec::new());
        }
        let tick = pusize(field(j, "tick")?, "tick")?;
        Ok(Adwin {
            delta,
            rows,
            total: varstats_from(field(j, "total")?, "total")?,
            tick: u32::try_from(tick).map_err(|_| anyhow!("adwin tick overflows u32"))?,
            n_detections: pusize(field(j, "n_detections")?, "n_detections")?,
            last_shrink_rise: pbool(field(j, "last_shrink_rise")?, "last_shrink_rise")?,
        })
    }

    fn n_buckets(&self) -> usize {
        self.rows.iter().map(Vec::len).sum()
    }

    /// Cascade row overflows upward, pairing the two oldest buckets of a
    /// row into one twice-as-large bucket of the next row (Chan merge).
    fn compress(&mut self) {
        let mut level = 0;
        while level < self.rows.len() {
            if self.rows[level].len() > MAX_BUCKETS {
                let a = self.rows[level].remove(0);
                let b = self.rows[level].remove(0);
                if level + 1 == self.rows.len() {
                    self.rows.push(Vec::new());
                }
                self.rows[level + 1].push(a + b);
            }
            level += 1;
        }
    }

    /// Drop stale buckets while any admissible cut shows significantly
    /// different sub-window means. Returns whether anything was dropped.
    fn shrink(&mut self) -> bool {
        if self.total.n < MIN_WINDOW {
            return false;
        }
        let mut detected = false;
        let mut dropped_acc = VarStats::new();
        while self.n_buckets() > 2 && self.has_cut() {
            // oldest bucket lives at the front of the highest row
            let level = self.rows.iter().rposition(|r| !r.is_empty()).expect("nonempty");
            let dropped = self.rows[level].remove(0);
            self.total = self.total - dropped;
            dropped_acc += dropped;
            while self.rows.len() > 1 && self.rows.last().map(Vec::is_empty).unwrap_or(false) {
                self.rows.pop();
            }
            detected = true;
        }
        if detected {
            self.n_detections += 1;
            self.last_shrink_rise = self.total.mean > dropped_acc.mean;
        }
        detected
    }

    /// Scan every bucket boundary oldest→newest for a significant cut.
    fn has_cut(&self) -> bool {
        let total = self.total;
        let var = total.variance_population();
        let delta_prime = self.delta / total.n.max(2.0);
        let ln_term = (2.0 / delta_prime).ln();
        let mut acc = VarStats::new();
        for level in (0..self.rows.len()).rev() {
            for bucket in &self.rows[level] {
                acc = acc + *bucket;
                let n0 = acc.n;
                let n1 = total.n - n0;
                if n0 < MIN_SIDE || n1 < MIN_SIDE {
                    continue;
                }
                let rest = total - acc;
                let m = 1.0 / n0 + 1.0 / n1;
                let eps = (2.0 * m * var * ln_term).sqrt() + 2.0 / 3.0 * m * ln_term;
                if (acc.mean - rest.mean).abs() > eps {
                    return true;
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::check;
    use crate::common::Rng;
    use crate::stream::synth::{Distribution, NoiseSpec, SyntheticRegression, TargetFn};
    use crate::stream::{AbruptDrift, Instance, Stream};

    /// A stream whose target is a constant level plus Gaussian noise —
    /// the drift building block (mirrors the wrapper in `stream::drift`
    /// tests).
    fn level_stream(level: f64, noise: f64, seed: u64) -> Box<dyn Stream> {
        struct Level {
            level: f64,
            noise: f64,
            rng: Rng,
            inner: SyntheticRegression,
        }
        impl Stream for Level {
            fn next_instance(&mut self) -> Option<Instance> {
                let mut inst = self.inner.next_instance().unwrap();
                inst.y = self.level + self.rng.normal(0.0, self.noise);
                Some(inst)
            }
            fn n_features(&self) -> usize {
                self.inner.n_features()
            }
            fn name(&self) -> String {
                format!("level{}", self.level)
            }
        }
        Box::new(Level {
            level,
            noise,
            rng: Rng::new(seed ^ 0xABCD),
            inner: SyntheticRegression::new(
                Distribution::Uniform { lo: -1.0, hi: 1.0 },
                TargetFn::Linear,
                NoiseSpec::NONE,
                1,
                seed,
            ),
        })
    }

    #[test]
    fn window_tracks_mean_when_stationary() {
        let mut adwin = Adwin::new(0.002);
        let mut rng = Rng::new(1);
        for _ in 0..2000 {
            adwin.update(rng.normal(3.0, 0.5));
        }
        assert!((adwin.mean() - 3.0).abs() < 0.1, "mean={}", adwin.mean());
        assert_eq!(adwin.width(), 2000, "stationary window must keep everything");
        assert_eq!(adwin.n_detections(), 0);
    }

    #[test]
    fn detects_mean_shift_on_abrupt_drift_stream() {
        let drift_at = 1500;
        let mut stream = AbruptDrift::new(
            level_stream(0.0, 0.5, 10),
            level_stream(2.0, 0.5, 11),
            drift_at,
        );
        let mut adwin = Adwin::new(0.002);
        let mut detected_at = None;
        for i in 0..4000 {
            let inst = stream.next_instance().unwrap();
            if adwin.update(inst.y) && detected_at.is_none() {
                detected_at = Some(i);
            }
        }
        let at = detected_at.expect("a 4-sigma mean shift must be detected");
        assert!(at >= drift_at, "detected before the drift: {at}");
        assert!(at < drift_at + 500, "detection too slow: {at}");
        assert!(adwin.rising(), "an upward shift must report rising");
        // after shrinking, the window mean reflects the new concept
        assert!((adwin.mean() - 2.0).abs() < 0.2, "mean={}", adwin.mean());
    }

    #[test]
    fn falling_shift_detected_but_not_rising() {
        let mut adwin = Adwin::new(0.002);
        let mut rng = Rng::new(19);
        for _ in 0..1000 {
            adwin.update(rng.normal(5.0, 0.3));
        }
        let mut detected = false;
        for _ in 0..1000 {
            detected |= adwin.update(rng.normal(1.0, 0.3));
        }
        assert!(detected, "a large downward shift must still shrink the window");
        assert!(!adwin.rising(), "downward shift must not report rising");
    }

    #[test]
    fn reset_clears_state() {
        let mut adwin = Adwin::new(0.01);
        for i in 0..100 {
            adwin.update(i as f64);
        }
        adwin.reset();
        assert_eq!(adwin.width(), 0);
        assert_eq!(adwin.n_detections(), 0);
        assert_eq!(adwin.mean(), 0.0);
    }

    #[test]
    fn json_roundtrip_fires_at_identical_instants() {
        let mut live = Adwin::new(0.002);
        let mut rng = Rng::new(47);
        for _ in 0..700 {
            live.update(rng.normal(0.0, 0.5));
        }
        let text = live.to_json().to_compact();
        let mut restored =
            Adwin::from_json(&crate::common::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(restored.width(), live.width());
        assert_eq!(restored.mean().to_bits(), live.mean().to_bits());
        // drive both through a mean shift: detections (and their rising
        // flags) must land on the same updates
        for _ in 0..800 {
            let v = rng.normal(3.0, 0.5);
            assert_eq!(live.update(v), restored.update(v));
            assert_eq!(live.rising(), restored.rising());
        }
        assert!(live.n_detections() >= 1, "shift must be detected");
        assert_eq!(restored.n_detections(), live.n_detections());
        assert_eq!(restored.width(), live.width());
    }

    #[test]
    fn bucket_memory_is_logarithmic() {
        let mut adwin = Adwin::new(0.002);
        let mut rng = Rng::new(3);
        for _ in 0..50_000 {
            adwin.update(rng.normal(0.0, 1.0));
        }
        // MAX_BUCKETS per row, ~log2(50k) rows
        assert!(adwin.n_buckets() <= MAX_BUCKETS * 20, "{} buckets", adwin.n_buckets());
        assert_eq!(adwin.width(), 50_000);
    }

    #[test]
    fn prop_never_fires_on_stationary_stream() {
        // the satellite contract: delta = 0.002 must produce no false
        // alarms on stationary noise (union-bounded cut test)
        check("adwin-stationary", 0xF0, 10, |rng| {
            let mut adwin = Adwin::new(0.002);
            let mu = rng.uniform(-5.0, 5.0);
            let sigma = 0.1 + rng.f64() * 2.0;
            for _ in 0..3000 {
                if adwin.update(rng.normal(mu, sigma)) {
                    return Err(format!("false alarm (mu={mu}, sigma={sigma})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_detects_large_shifts_quickly() {
        check("adwin-detects-shift", 0xF1, 10, |rng| {
            let mut adwin = Adwin::new(0.002);
            let sigma = 0.5;
            let jump = 4.0 + rng.f64() * 4.0; // 8..16 sigma shift
            for _ in 0..1000 {
                adwin.update(rng.normal(0.0, sigma));
            }
            for i in 0..500 {
                if adwin.update(rng.normal(jump, sigma)) {
                    return if i < 200 {
                        Ok(())
                    } else {
                        Err(format!("slow detection: {i} samples for a {jump}-shift"))
                    };
                }
            }
            Err(format!("missed a {jump} mean shift"))
        });
    }
}
