//! # qostream
//!
//! A rust online-machine-learning framework reproducing
//! *"Using dynamical quantization to perform split attempts in online tree
//! regressors"* (Mastelini & de Carvalho, 2020).
//!
//! The paper contributes the **Quantization Observer (QO)**: a hashing-based
//! attribute observer with O(1) insertion and sub-linear split-candidate
//! queries for online regression trees, plus numerically robust
//! *mergeable and subtractable* variance estimators (Welford updates with
//! the Chan et al. parallel formulas extended with subtraction).
//!
//! This crate provides:
//!
//! * [`stats`] — the robust streaming statistics (paper Sec. 3) plus the
//!   Friedman/Nemenyi machinery used by the paper's evaluation.
//! * [`observer`] — QO (paper Sec. 4), E-BST, TE-BST and an exhaustive
//!   oracle, all behind one [`observer::AttributeObserver`] trait.
//! * [`criterion`] — split-merit heuristics (Variance Reduction, Eq. 1).
//! * [`tree`] — a FIMT-like Hoeffding Tree Regressor with pluggable
//!   observers (the paper's target integration, its Sec. 7 future work).
//! * [`forest`] — online ensembles over those trees: ADWIN drift
//!   detection, Oza–Russell online bagging, an Adaptive Random Forest
//!   Regressor with per-leaf random feature subspaces, and parallel
//!   member fitting that reuses the [`coordinator`] channel machinery.
//! * [`stream`] — synthetic generators implementing the paper's Table 1
//!   protocol, drift wrappers and a CSV reader.
//! * [`eval`] — prequential evaluation and incremental regression metrics.
//! * [`coordinator`] — sharded streaming runtimes: data-parallel observer
//!   sharding (exploiting the mergeability of the Sec. 3 statistics) and
//!   model-parallel forest member sharding with one split-backend
//!   round-trip per shard per tick.
//! * [`runtime`] — a PJRT/XLA backend that executes the AOT-compiled
//!   JAX/Pallas split-evaluation artifacts from `artifacts/`.
//! * [`persist`] — the versioned JSON model codec: `save → load` is
//!   bit-for-bit invisible to prediction *and* continued training, for
//!   trees, forests and every observer kind; [`persist::delta`] turns
//!   consecutive checkpoints into exact structural deltas (versioned,
//!   hash-verified) for replication.
//! * [`serve`] — a std-only TCP learn/predict server: one trainer thread
//!   owns the mutable model (optionally sharded over the coordinator),
//!   reader threads answer predictions from immutable hot-swapped
//!   snapshots, checkpoints on demand, and follower read replicas
//!   ([`serve::replicate`]) mirror the leader via delta checkpoints.
//! * [`bench_suite`] — regenerates every table and figure of the paper's
//!   evaluation (see DESIGN.md for the experiment index), plus the
//!   serving latency/checkpoint-size scenario.
//! * [`govern`] — memory-governed serving: given a byte budget, an
//!   escalation ladder (exact QO slot compaction → cold-leaf observer
//!   eviction → worst-member pruning) keeps a forever-training model
//!   inside fixed RAM; governed checkpoints carry an auditable budget
//!   claim (see `docs/MEMORY.md`).
//! * [`obs`] — dependency-free observability: a lock-free metrics
//!   registry (atomic counters/gauges + log2-bucketed histograms with
//!   exact merge and p50/p90/p99 readout), a bounded split-decision
//!   trace ring, and Prometheus text exposition — no-ops when disabled,
//!   served live via the `metrics` / `trace_splits` protocol commands.
//! * [`audit`] — the static-analysis gate: a model-invariant verifier
//!   over checkpoint documents and delta chains (arena topology, QO slot
//!   tables, E-BST ordering, hash-chain continuity — rule catalog in
//!   `docs/INVARIANTS.md`) plus a std-only repo lint pass, both emitting
//!   structured findings; wired into the CLI (`qostream audit`), the
//!   persist/serve/replicate boundaries, and CI.
//! * [`common`] — zero-dependency substrate: PRNG, JSON reader/writer,
//!   ASCII tables/plots, a tiny property-testing harness, CLI parsing.

#![forbid(unsafe_code)]

pub mod audit;
pub mod bench_suite;
pub mod common;
pub mod coordinator;
pub mod criterion;
pub mod eval;
pub mod forest;
pub mod govern;
pub mod obs;
pub mod observer;
pub mod persist;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod stream;
pub mod tree;
