//! `govern/` — memory-governed serving: keep a model inside a fixed
//! byte budget, forever.
//!
//! The paper's QO observer already bounds *per-leaf* monitoring cost
//! (hash slots instead of a BST over every distinct value, PAPER.md
//! Sec. 4), but a tree that keeps splitting — or a forest that keeps
//! re-seeding background trees — still grows without bound. This module
//! adds the missing control loop on top of the `mem_bytes()` accounting
//! that every layer already exposes: given a budget, escalate through
//! three increasingly lossy steps until the model fits.
//!
//! ## The escalation ladder
//!
//! * **(a) Compact** — merge adjacent QO slot pairs in place
//!   ([`QuantizationObserver::compact`]) at a shrinking per-observer
//!   slot target (64 → 32 → … → 2). *Exact* for the stored statistics:
//!   the merged [`crate::stats::VarStats`] is bit-identical to having
//!   observed both slots' populations into one (the paper's Sec. 3
//!   mergeability), so predictions are untouched and only split-point
//!   *resolution* coarsens.
//! * **(b) Evict** — deactivate observers on the coldest leaves
//!   ([`HoeffdingTreeRegressor::evict_coldest`]), coldest = least
//!   weight since the last split attempt. Same semantics as the
//!   max-depth freeze: the leaf still predicts and adapts its target
//!   mean, it just stops attempting splits.
//! * **(c) Prune** — drop the ensemble member with the worst recent
//!   prequential error (`prune_worst`, the PR 4 inverse-error EWMAs);
//!   the last member always survives.
//!
//! Each step only runs while the model is still over budget, so a
//! generous budget never costs accuracy. When even the full ladder
//! cannot fit (the budget is below the structural skeleton of one
//! member), [`GovernReport::within_budget`] is `false` — the caller
//! (the serve trainer, the CLI) surfaces that instead of thrashing.
//!
//! ## Hot-path contract
//!
//! The per-batch check is [`Governor::over_budget`]: one integer
//! compare, no allocation, no model walk — the caller passes the
//! `mem_bytes()` it already computes for the `qostream_model_mem_bytes`
//! gauge. `tools/lint` pins this (`LINT_GOVERN_HOT_PATH`): the check
//! must stay allocation-free; only a *triggered* [`Governor::enforce`]
//! may allocate. The serve trainer runs the check between
//! `train_batch` and `stage_publish`, so snapshots, replication deltas
//! and audits only ever see governed state — followers receive it
//! through ordinary deltas, no protocol change (`docs/MEMORY.md`).
//!
//! ## Checkpoint claims
//!
//! Governed checkpoints carry two extra envelope keys
//! ([`stamp_governed`]): the budget and the `mem_bytes()` measured at
//! save time. Loaders ignore unknown envelope keys, so the stamp is
//! wire-compatible with every prior reader; `qostream audit` verifies
//! the claim (`GOVERN_BUDGET` in `docs/INVARIANTS.md`).

use crate::common::json::Json;
use crate::persist::codec::{jusize, pusize};
use crate::persist::Model;
use anyhow::Result;

#[cfg(doc)]
use crate::observer::QuantizationObserver;
#[cfg(doc)]
use crate::tree::HoeffdingTreeRegressor;

/// Envelope key carrying the byte budget a checkpoint was governed to.
pub const BUDGET_KEY: &str = "mem_budget";

/// Envelope key carrying the `mem_bytes()` measured at save time.
pub const CLAIM_KEY: &str = "mem_bytes";

/// Per-observer slot targets step (a) walks, largest first. Each rung
/// roughly halves the previous one; the floor of 2 matches
/// [`QuantizationObserver::compact`]'s minimum (a split needs two
/// candidate partitions).
pub const COMPACT_TARGETS: &[usize] = &[64, 32, 16, 8, 4, 2];

/// What one [`Governor::enforce`] pass did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GovernReport {
    /// `mem_bytes()` when the pass started.
    pub start_bytes: usize,
    /// `mem_bytes()` when the pass finished.
    pub end_bytes: usize,
    /// Observers whose slot tables shrank in step (a).
    pub compactions: u64,
    /// Leaves whose observers were deactivated in step (b).
    pub evictions: u64,
    /// Ensemble members dropped in step (c).
    pub prunes: u64,
    /// Did the model end the pass at or under budget? `false` means the
    /// budget is below the structural floor (one member's skeleton).
    pub within_budget: bool,
}

impl GovernReport {
    /// Did this pass change the model at all?
    pub fn acted(&self) -> bool {
        self.compactions > 0 || self.evictions > 0 || self.prunes > 0
    }
}

/// The budget enforcer. Cheap to construct and `Copy` — the serve
/// trainer keeps one by value.
#[derive(Clone, Copy, Debug)]
pub struct Governor {
    /// Byte budget; 0 means unbounded (every check passes).
    budget: usize,
}

impl Governor {
    /// A governor for `budget` bytes; 0 disables governance.
    pub fn new(budget: usize) -> Governor {
        Governor { budget }
    }

    /// The configured budget (0 = unbounded).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Is governance enabled at all?
    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    /// The hot-path check: one integer compare against a `mem_bytes()`
    /// the caller already holds. No allocation, no model walk —
    /// `tools/lint` (`LINT_GOVERN_HOT_PATH`) keeps it that way.
    #[inline(always)]
    pub fn over_budget(&self, mem_bytes: usize) -> bool {
        self.budget != 0 && mem_bytes > self.budget
    }

    /// Run the escalation ladder until `model.mem_bytes()` fits the
    /// budget (or nothing more can be shed). A no-op — and allocation
    /// free — when the model already fits. Updates the `govern_*`
    /// counters and the `mem_budget` / `mem_bytes` gauges when the
    /// metrics registry is enabled.
    pub fn enforce(&self, model: &mut Model) -> GovernReport {
        let start = model.mem_bytes();
        let mut report = GovernReport {
            start_bytes: start,
            end_bytes: start,
            within_budget: !self.over_budget(start),
            ..GovernReport::default()
        };
        if report.within_budget {
            return report;
        }
        // (a) compact QO slot tables, coarsest target first
        for &target in COMPACT_TARGETS {
            report.compactions += compact(model, target) as u64;
            report.end_bytes = model.mem_bytes();
            if !self.over_budget(report.end_bytes) {
                break;
            }
        }
        // (b) evict the coldest leaves, one per tree per round, until
        // the model fits or no active leaves remain
        while self.over_budget(report.end_bytes) {
            let evicted = evict(model, 1);
            if evicted == 0 {
                break;
            }
            report.evictions += evicted as u64;
            report.end_bytes = model.mem_bytes();
        }
        // (c) prune the worst ensemble member (never the last one)
        while self.over_budget(report.end_bytes) {
            if prune(model).is_none() {
                break;
            }
            report.prunes += 1;
            report.end_bytes = model.mem_bytes();
        }
        report.within_budget = !self.over_budget(report.end_bytes);
        if let Some(m) = crate::obs::m() {
            m.govern_compactions.add(report.compactions);
            m.govern_evictions.add(report.evictions);
            m.govern_prunes.add(report.prunes);
            m.mem_budget_bytes.set(self.budget as u64);
            m.model_mem_bytes.set(report.end_bytes as u64);
        }
        report
    }
}

/// Step (a) dispatch: compact every QO observer in the model to at most
/// `target_slots` slots. Returns how many observers shrank.
fn compact(model: &mut Model, target_slots: usize) -> usize {
    match model {
        Model::Tree(t) => t.compact_observers(target_slots),
        Model::Arf(f) => f.compact_observers(target_slots),
        Model::Bagging(b) => b.compact_observers(target_slots),
    }
}

/// Step (b) dispatch: evict the `per_tree` coldest active leaves of
/// every tree in the model. Returns how many leaves were deactivated.
fn evict(model: &mut Model, per_tree: usize) -> usize {
    match model {
        Model::Tree(t) => t.evict_coldest(per_tree),
        Model::Arf(f) => f.evict_coldest(per_tree),
        Model::Bagging(b) => b.evict_coldest(per_tree),
    }
}

/// Step (c) dispatch: drop the worst ensemble member. `None` for plain
/// trees (nothing to prune) and for ensembles already at one member.
fn prune(model: &mut Model) -> Option<usize> {
    match model {
        Model::Tree(_) => None,
        Model::Arf(f) => f.prune_worst(),
        Model::Bagging(b) => b.prune_worst(),
    }
}

/// Stamp a checkpoint document as governed: record the budget and the
/// `mem_bytes()` measured at save time as envelope keys. Loaders that
/// predate governance ignore unknown envelope keys, so the stamped
/// document stays readable everywhere; `qostream audit` verifies the
/// claim (`GOVERN_BUDGET`).
pub fn stamp_governed(doc: &mut Json, budget: usize, mem_bytes: usize) {
    doc.set(BUDGET_KEY, jusize(budget));
    doc.set(CLAIM_KEY, jusize(mem_bytes));
}

/// Read a governed stamp back: `Ok(Some((budget, claimed_mem_bytes)))`
/// when both keys are present, `Ok(None)` for ungoverned checkpoints,
/// `Err` when the keys exist but do not parse (a corrupt or forged
/// stamp — the audit canary exercises this).
pub fn governed_claim(doc: &Json) -> Result<Option<(usize, usize)>> {
    match (doc.get(BUDGET_KEY), doc.get(CLAIM_KEY)) {
        (None, None) => Ok(None),
        (budget, claim) => {
            let budget = match budget {
                Some(b) => pusize(b, BUDGET_KEY)?,
                None => 0,
            };
            let claimed = match claim {
                Some(c) => pusize(c, CLAIM_KEY)?,
                None => 0,
            };
            Ok(Some((budget, claimed)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Regressor;
    use crate::forest::{ArfOptions, ArfRegressor};
    use crate::observer::{factory, QuantizationObserver, RadiusPolicy};
    use crate::stream::{Friedman1, Stream};
    use crate::tree::{HoeffdingTreeRegressor, HtrOptions};

    fn qo_factory() -> Box<dyn crate::observer::ObserverFactory> {
        factory("QO_0.01", || {
            Box::new(QuantizationObserver::new(RadiusPolicy::fixed(0.01)))
        })
    }

    fn grown_tree(n: usize) -> HoeffdingTreeRegressor {
        let mut tree =
            HoeffdingTreeRegressor::new(10, HtrOptions::default(), qo_factory());
        let mut stream = Friedman1::new(5, 1.0);
        for _ in 0..n {
            let inst = stream.next_instance().unwrap();
            tree.learn_one(&inst.x, inst.y);
        }
        tree
    }

    #[test]
    fn unbounded_and_roomy_budgets_are_no_ops() {
        let mut model = Model::Tree(grown_tree(3000));
        let before = model.mem_bytes();
        let r = Governor::new(0).enforce(&mut model);
        assert!(r.within_budget && !r.acted());
        assert_eq!(model.mem_bytes(), before, "unbounded must not touch the model");
        let r = Governor::new(before * 2).enforce(&mut model);
        assert!(r.within_budget && !r.acted());
        assert_eq!(model.mem_bytes(), before, "roomy budget must not touch the model");
    }

    #[test]
    fn compaction_alone_satisfies_a_mild_budget() {
        // QO_0.01 tables are dense: halving the footprint is reachable
        // by step (a) alone, and predictions stay bit-identical
        let mut model = Model::Tree(grown_tree(6000));
        let probe = [0.3; 10];
        let before_pred = model.predict(&probe);
        let start = model.mem_bytes();
        let governor = Governor::new(start * 7 / 10);
        let r = governor.enforce(&mut model);
        assert!(r.within_budget, "mild budget must be reachable: {r:?}");
        assert!(r.compactions > 0);
        assert_eq!(r.evictions, 0, "compaction sufficed; eviction must not fire: {r:?}");
        assert_eq!(r.prunes, 0);
        assert_eq!(r.end_bytes, model.mem_bytes());
        assert!(r.end_bytes <= governor.budget());
        assert_eq!(model.predict(&probe).to_bits(), before_pred.to_bits());
    }

    #[test]
    fn tight_budget_escalates_to_eviction() {
        let mut model = Model::Tree(grown_tree(6000));
        // below what compaction alone can reach, above the bare skeleton
        let skeleton = {
            let mut clone = match &model {
                Model::Tree(t) => Model::Tree(t.clone()),
                _ => unreachable!(),
            };
            Governor::new(1).enforce(&mut clone);
            clone.mem_bytes()
        };
        let budget = skeleton + (model.mem_bytes() - skeleton) / 20;
        let r = Governor::new(budget).enforce(&mut model);
        assert!(r.within_budget, "evictions must reach the budget: {r:?}");
        assert!(r.evictions > 0, "expected eviction to fire: {r:?}");
        assert!(model.mem_bytes() <= budget);
        // the governed model still predicts (frozen leaves keep their
        // target statistics)
        assert!(model.predict(&[0.3; 10]).is_finite());
    }

    #[test]
    fn impossible_budget_stops_at_the_structural_floor() {
        let mut model = Model::Tree(grown_tree(2000));
        let r = Governor::new(1).enforce(&mut model);
        assert!(!r.within_budget, "1 byte cannot hold a tree: {r:?}");
        assert!(r.acted());
        // a second pass finds nothing more to shed and reports honestly
        let r2 = Governor::new(1).enforce(&mut model);
        assert!(!r2.within_budget);
        assert_eq!(r2.compactions, 0);
        assert_eq!(r2.evictions, 0);
        assert_eq!(model.mem_bytes(), r.end_bytes, "floor must be stable");
    }

    #[test]
    fn forest_escalation_prunes_down_to_one_member() {
        let mut arf = ArfRegressor::new(
            10,
            ArfOptions { n_members: 3, seed: 17, ..ArfOptions::default() },
            qo_factory(),
        );
        let mut stream = Friedman1::new(5, 1.0);
        for _ in 0..3000 {
            let inst = stream.next_instance().unwrap();
            arf.learn_one(&inst.x, inst.y);
        }
        let mut model = Model::Arf(arf);
        let r = Governor::new(1).enforce(&mut model);
        assert_eq!(r.prunes, 2, "must prune down to the last member: {r:?}");
        assert!(!r.within_budget);
        let Model::Arf(arf) = &model else { unreachable!() };
        assert_eq!(arf.n_members(), 1, "last member survives");
    }

    #[test]
    fn enforce_feeds_the_govern_metric_families() {
        let _toggling = crate::obs::toggle_lock();
        crate::obs::enable();
        let g = crate::obs::global();
        let (c0, e0) =
            (g.govern_compactions.get(), g.govern_evictions.get());
        let mut model = Model::Tree(grown_tree(5000));
        let budget = model.mem_bytes() * 7 / 10;
        let r = Governor::new(budget).enforce(&mut model);
        assert!(r.acted());
        assert!(g.govern_compactions.get() >= c0 + r.compactions);
        assert!(g.govern_evictions.get() >= e0 + r.evictions);
        assert_eq!(g.mem_budget_bytes.get(), budget as u64);
    }

    #[test]
    fn governed_stamp_roundtrips_and_rejects_garbage() {
        let model = Model::Tree(grown_tree(500));
        let mut doc = model.to_checkpoint().unwrap();
        assert_eq!(governed_claim(&doc).unwrap(), None, "ungoverned has no claim");
        let mem = model.mem_bytes();
        stamp_governed(&mut doc, 1 << 20, mem);
        assert_eq!(governed_claim(&doc).unwrap(), Some((1 << 20, mem)));
        // the stamped envelope still loads everywhere (unknown envelope
        // keys are ignored by design)
        let back = Model::from_checkpoint(&doc).unwrap();
        assert_eq!(back.mem_bytes(), mem);
        // a forged non-numeric stamp is an error, not a silent None
        doc.set(CLAIM_KEY, "not-a-number");
        assert!(governed_claim(&doc).is_err());
    }
}
