//! Streaming statistics (paper Sec. 3) and the evaluation statistics
//! (Friedman + Nemenyi, Demšar 2006) used by the paper's Figures 2/4/5/6.

pub mod friedman;
pub mod gamma;
pub mod naive;
pub mod nemenyi;
pub mod welford;

pub use friedman::{friedman_test, FriedmanResult};
pub use naive::NaiveVarStats;
pub use nemenyi::{critical_difference, render_cd_diagram, NemenyiResult};
pub use welford::VarStats;
