//! Friedman rank test over multiple datasets × multiple algorithms
//! (Demšar 2006), used by the paper's Figures 2, 4, 5 and 6.

use super::gamma::{chi2_sf, f_sf};

/// Result of a Friedman test.
#[derive(Clone, Debug)]
pub struct FriedmanResult {
    /// Average rank of each algorithm (1 = best); lower is better.
    pub avg_ranks: Vec<f64>,
    /// Friedman chi-square statistic χ²_F.
    pub chi2: f64,
    /// p-value of χ²_F against the chi-square(k−1) distribution.
    pub p_chi2: f64,
    /// Iman–Davenport corrected statistic F_F.
    pub f_stat: f64,
    /// p-value of F_F against F(k−1, (k−1)(N−1)).
    pub p_f: f64,
    /// Number of datasets N and algorithms k.
    pub n_datasets: usize,
    pub n_algorithms: usize,
}

impl FriedmanResult {
    pub fn significant(&self, alpha: f64) -> bool {
        self.p_f < alpha
    }
}

/// Rank one row of measurements (lower = better ⇒ rank 1), ties get the
/// average of the tied rank span.
pub fn rank_row(values: &[f64]) -> Vec<f64> {
    let k = values.len();
    let mut idx: Vec<usize> = (0..k).collect();
    idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut ranks = vec![0.0; k];
    let mut i = 0;
    while i < k {
        let mut j = i;
        while j + 1 < k && values[idx[j + 1]] == values[idx[i]] {
            j += 1;
        }
        // positions i..=j are tied: average rank (1-based)
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &slot in &idx[i..=j] {
            ranks[slot] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Friedman test. `measurements[d][a]` is the metric of algorithm `a` on
/// dataset `d`. `lower_is_better` controls the ranking direction.
pub fn friedman_test(measurements: &[Vec<f64>], lower_is_better: bool) -> FriedmanResult {
    let n = measurements.len();
    assert!(n >= 2, "need at least 2 datasets");
    let k = measurements[0].len();
    assert!(k >= 2, "need at least 2 algorithms");

    let mut rank_sums = vec![0.0; k];
    for row in measurements {
        assert_eq!(row.len(), k, "ragged measurement matrix");
        let keyed: Vec<f64> = if lower_is_better {
            row.clone()
        } else {
            row.iter().map(|v| -v).collect()
        };
        for (a, r) in rank_row(&keyed).into_iter().enumerate() {
            rank_sums[a] += r;
        }
    }
    let avg_ranks: Vec<f64> = rank_sums.iter().map(|s| s / n as f64).collect();

    let nf = n as f64;
    let kf = k as f64;
    let sum_r2: f64 = avg_ranks.iter().map(|r| r * r).sum();
    let chi2 = 12.0 * nf / (kf * (kf + 1.0)) * (sum_r2 - kf * (kf + 1.0) * (kf + 1.0) / 4.0);
    let p_chi2 = chi2_sf(chi2, kf - 1.0);
    // Iman–Davenport correction
    let denom = nf * (kf - 1.0) - chi2;
    let (f_stat, p_f) = if denom > 0.0 {
        let f = (nf - 1.0) * chi2 / denom;
        (f, f_sf(f, kf - 1.0, (kf - 1.0) * (nf - 1.0)))
    } else {
        (f64::INFINITY, 0.0)
    };

    FriedmanResult { avg_ranks, chi2, p_chi2, f_stat, p_f, n_datasets: n, n_algorithms: k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_row_basic() {
        assert_eq!(rank_row(&[0.3, 0.1, 0.2]), vec![3.0, 1.0, 2.0]);
    }

    #[test]
    fn rank_row_ties_averaged() {
        assert_eq!(rank_row(&[1.0, 1.0, 2.0]), vec![1.5, 1.5, 3.0]);
        assert_eq!(rank_row(&[5.0, 5.0, 5.0]), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn clear_winner_detected() {
        // algo 0 always best (lowest), algo 2 always worst
        let data: Vec<Vec<f64>> =
            (0..20).map(|d| vec![1.0 + d as f64, 2.0 + d as f64, 3.0 + d as f64]).collect();
        let res = friedman_test(&data, true);
        assert_eq!(res.avg_ranks, vec![1.0, 2.0, 3.0]);
        assert!(res.p_chi2 < 0.001, "p={}", res.p_chi2);
        assert!(res.significant(0.05));
    }

    #[test]
    fn higher_is_better_flips_ranks() {
        let data: Vec<Vec<f64>> = (0..10).map(|_| vec![1.0, 2.0]).collect();
        let res = friedman_test(&data, false);
        assert_eq!(res.avg_ranks, vec![2.0, 1.0]);
    }

    #[test]
    fn no_difference_not_significant() {
        // alternate winners evenly
        let data: Vec<Vec<f64>> = (0..20)
            .map(|d| if d % 2 == 0 { vec![1.0, 2.0] } else { vec![2.0, 1.0] })
            .collect();
        let res = friedman_test(&data, true);
        assert!((res.avg_ranks[0] - 1.5).abs() < 1e-12);
        assert!(res.p_chi2 > 0.5);
        assert!(!res.significant(0.05));
    }

    #[test]
    fn demsar_textbook_example() {
        // Demšar (2006) Table 6 shape: 4 algorithms, 14 datasets.
        // We verify χ² matches the hand formula on a small crafted case.
        let data = vec![
            vec![0.1, 0.2, 0.3, 0.4],
            vec![0.2, 0.1, 0.4, 0.3],
            vec![0.1, 0.2, 0.4, 0.3],
            vec![0.1, 0.3, 0.2, 0.4],
            vec![0.1, 0.2, 0.3, 0.4],
            vec![0.2, 0.1, 0.3, 0.4],
        ];
        let res = friedman_test(&data, true);
        // manual: rank sums per column
        let expected_avg = [1.333_333_333, 1.833_333_333, 3.166_666_667, 3.666_666_667];
        for (got, want) in res.avg_ranks.iter().zip(expected_avg.iter()) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
        assert!(res.chi2 > 0.0 && res.p_chi2 < 0.05);
    }
}
