//! Nemenyi post-hoc test and ASCII critical-difference diagrams
//! (Demšar 2006) — the rendering used for the paper's Figures 2/4/5/6.

use super::friedman::FriedmanResult;

/// Critical values q_α of the studentized range statistic divided by √2,
/// for α = 0.05 and k = 2..=10 algorithms (Demšar 2006, Table 5a).
const Q_ALPHA_005: [f64; 9] =
    [1.960, 2.343, 2.569, 2.728, 2.850, 2.949, 3.031, 3.102, 3.164];

/// Critical values for α = 0.10 (Demšar 2006, Table 5b).
const Q_ALPHA_010: [f64; 9] =
    [1.645, 2.052, 2.291, 2.459, 2.589, 2.693, 2.780, 2.855, 2.920];

/// Nemenyi critical difference CD = q_α √(k(k+1)/(6N)).
pub fn critical_difference(k: usize, n: usize, alpha: f64) -> f64 {
    assert!((2..=10).contains(&k), "q_alpha table covers k in 2..=10");
    let q = if (alpha - 0.05).abs() < 1e-9 {
        Q_ALPHA_005[k - 2]
    } else if (alpha - 0.10).abs() < 1e-9 {
        Q_ALPHA_010[k - 2]
    } else {
        panic!("alpha must be 0.05 or 0.10 (tabled values)");
    };
    q * ((k * (k + 1)) as f64 / (6.0 * n as f64)).sqrt()
}

/// Pairwise Nemenyi outcome.
#[derive(Clone, Debug)]
pub struct NemenyiResult {
    pub cd: f64,
    pub avg_ranks: Vec<f64>,
    /// `true` at (i, j) when algorithms i and j are NOT significantly
    /// different (|rank_i − rank_j| < CD).
    pub indistinct: Vec<Vec<bool>>,
}

/// Run the Nemenyi post-hoc on a Friedman result.
pub fn nemenyi(friedman: &FriedmanResult, alpha: f64) -> NemenyiResult {
    let k = friedman.n_algorithms;
    let cd = critical_difference(k, friedman.n_datasets, alpha);
    let mut indistinct = vec![vec![false; k]; k];
    for i in 0..k {
        for j in 0..k {
            indistinct[i][j] = (friedman.avg_ranks[i] - friedman.avg_ranks[j]).abs() < cd;
        }
    }
    NemenyiResult { cd, avg_ranks: friedman.avg_ranks.clone(), indistinct }
}

/// Render an ASCII critical-difference diagram:
///
/// ```text
/// CD = 0.87   (k=5, N=684, alpha=0.05)
/// 1.0                                         5.0
/// |---------|---------|---------|---------|
///    QO_s2 (1.52) ────┐
///    QO_s3 (1.71) ────┤          <- bars join groups not separable at CD
/// ```
///
/// The textual form lists each algorithm at its average rank and draws
/// group bars for cliques of mutually indistinct algorithms.
pub fn render_cd_diagram(names: &[String], result: &NemenyiResult) -> String {
    let k = names.len();
    assert_eq!(k, result.avg_ranks.len());
    let width = 61usize; // rank axis 1..k mapped onto this many columns
    let rank_to_col = |r: f64| -> usize {
        let frac = (r - 1.0) / ((k as f64 - 1.0).max(1e-9));
        (frac.clamp(0.0, 1.0) * (width - 1) as f64).round() as usize
    };

    let mut out = String::new();
    out.push_str(&format!("CD = {:.4} (alpha on avg ranks 1..{k})\n", result.cd));

    // axis
    let mut axis = vec![b'-'; width];
    for t in 0..k {
        axis[rank_to_col(t as f64 + 1.0)] = b'+';
    }
    out.push_str(&format!("rank: 1{:>pad$}\n", k, pad = width - 1));
    out.push_str(&format!("      {}\n", String::from_utf8(axis).unwrap()));

    // CD ruler
    let cd_cols = ((result.cd / ((k as f64 - 1.0).max(1e-9))) * (width - 1) as f64).round() as usize;
    out.push_str(&format!(
        "      |{}| = CD\n",
        "=".repeat(cd_cols.clamp(1, width.saturating_sub(2)))
    ));

    // algorithms sorted by rank
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| result.avg_ranks[a].partial_cmp(&result.avg_ranks[b]).unwrap());
    for &i in &order {
        let col = rank_to_col(result.avg_ranks[i]);
        out.push_str(&format!(
            "      {}^ {} ({:.3})\n",
            " ".repeat(col),
            names[i],
            result.avg_ranks[i]
        ));
    }

    // maximal groups of mutually indistinct algorithms (by rank order)
    let mut groups: Vec<(usize, usize)> = Vec::new();
    for s in 0..k {
        let mut e = s;
        'grow: for t in s + 1..k {
            for u in s..=t {
                for v in s..=t {
                    if !result.indistinct[order[u]][order[v]] {
                        break 'grow;
                    }
                }
            }
            e = t;
        }
        if e > s && !groups.iter().any(|&(gs, ge)| gs <= s && e <= ge) {
            groups.push((s, e));
        }
    }
    for (gi, &(s, e)) in groups.iter().enumerate() {
        let c0 = rank_to_col(result.avg_ranks[order[s]]);
        let c1 = rank_to_col(result.avg_ranks[order[e]]);
        let (c0, c1) = (c0.min(c1), c0.max(c1));
        out.push_str(&format!(
            "      {}{} group{}: {}\n",
            " ".repeat(c0),
            "█".repeat((c1 - c0 + 1).max(1)),
            gi + 1,
            order[s..=e].iter().map(|&i| names[i].as_str()).collect::<Vec<_>>().join(", ")
        ));
    }
    if groups.is_empty() {
        out.push_str("      (all pairwise differences exceed CD)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::friedman::friedman_test;

    #[test]
    fn cd_formula_matches_demsar() {
        // Demšar 2006: k=5, N=30 -> CD = 2.728 * sqrt(5*6/(6*30)) = 1.113...
        let cd = critical_difference(5, 30, 0.05);
        assert!((cd - 2.728 * (30.0f64 / 180.0).sqrt()).abs() < 1e-9);
        assert!((cd - 1.1136).abs() < 1e-3, "cd={cd}");
    }

    #[test]
    fn cd_alpha_010_smaller() {
        assert!(critical_difference(5, 30, 0.10) < critical_difference(5, 30, 0.05));
    }

    #[test]
    #[should_panic(expected = "q_alpha table")]
    fn k_out_of_table_panics() {
        critical_difference(11, 10, 0.05);
    }

    #[test]
    fn nemenyi_groups_and_diagram() {
        // 3 algorithms: 0 and 1 close together, 2 far away, many datasets
        let data: Vec<Vec<f64>> = (0..40)
            .map(|d| {
                if d % 2 == 0 {
                    vec![1.0, 1.1, 5.0]
                } else {
                    vec![1.1, 1.0, 5.0]
                }
            })
            .collect();
        let fr = friedman_test(&data, true);
        let ne = nemenyi(&fr, 0.05);
        assert!(ne.indistinct[0][1], "0 and 1 should be indistinct");
        assert!(!ne.indistinct[0][2], "0 and 2 should differ");
        let names = vec!["a".to_string(), "b".to_string(), "c".to_string()];
        let diagram = render_cd_diagram(&names, &ne);
        assert!(diagram.contains("CD ="));
        assert!(diagram.contains("a ("));
        assert!(diagram.contains("group1"));
    }
}
