//! Robust incremental mean/variance (paper Sec. 3).
//!
//! [`VarStats`] keeps the Welford triple `(n, mean, M2)`:
//!
//! * **update** — Welford's algorithm (Eqs. 2–3), weighted;
//! * **merge** (`+`) — Chan et al. parallel combination (Eqs. 4–5);
//! * **subtract** (`-`) — the paper's extension (Eqs. 6–7), recovering the
//!   complement of a partial estimate.
//!
//! These two closure properties are what let E-BST-style observers compute
//! right-hand statistics as `total - left`, and what lets the
//! [`crate::coordinator`] merge per-shard partial observations losslessly.

use std::ops::{Add, AddAssign, Sub, SubAssign};

/// Robust mergeable/subtractable variance estimator.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct VarStats {
    /// Total observed weight (count when unweighted).
    pub n: f64,
    /// Running mean of the target.
    pub mean: f64,
    /// Second-order central moment accumulator (Σ w (y − ȳ)²).
    pub m2: f64,
}

impl VarStats {
    pub const EMPTY: VarStats = VarStats { n: 0.0, mean: 0.0, m2: 0.0 };

    #[inline]
    pub fn new() -> VarStats {
        VarStats::EMPTY
    }

    /// A single observation with weight `w` (paper Alg. 1's `s²_{y_i}`).
    #[inline]
    pub fn from_one(y: f64, w: f64) -> VarStats {
        VarStats { n: w, mean: y, m2: 0.0 }
    }

    /// Build from a slice (test/bootstrap convenience).
    pub fn from_slice(ys: &[f64]) -> VarStats {
        let mut s = VarStats::new();
        for &y in ys {
            s.update(y, 1.0);
        }
        s
    }

    /// Weighted Welford update (Eqs. 2–3 with weight `w`).
    #[inline]
    pub fn update(&mut self, y: f64, w: f64) {
        if w <= 0.0 {
            return;
        }
        self.n += w;
        let delta = y - self.mean;
        self.mean += (w / self.n) * delta;
        self.m2 += w * delta * (y - self.mean);
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n <= 0.0
    }

    /// Σ w·y reconstructed from the kept moments.
    #[inline]
    pub fn sum(&self) -> f64 {
        self.n * self.mean
    }

    /// Sample variance s² = M2 / (n − 1); 0 when n ≤ 1.
    #[inline]
    pub fn variance(&self) -> f64 {
        if self.n > 1.0 {
            (self.m2 / (self.n - 1.0)).max(0.0)
        } else {
            0.0
        }
    }

    /// Population variance M2 / n; 0 when n ≤ 0.
    #[inline]
    pub fn variance_population(&self) -> f64 {
        if self.n > 0.0 {
            (self.m2 / self.n).max(0.0)
        } else {
            0.0
        }
    }

    /// Sample standard deviation.
    #[inline]
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Chan et al. merge (Eqs. 4–5).
    #[inline]
    pub fn merged(&self, other: &VarStats) -> VarStats {
        if self.is_empty() {
            return *other;
        }
        if other.is_empty() {
            return *self;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        VarStats {
            n,
            mean: (self.n * self.mean + other.n * other.mean) / n,
            m2: self.m2 + other.m2 + delta * delta * (self.n * other.n / n),
        }
    }

    /// The paper's subtraction extension (Eqs. 6–7): `self` is the AB
    /// total, `other` is the B part; returns A. Tiny negative `m2` from
    /// cancellation is clamped to 0; non-positive remaining weight yields
    /// the empty estimator.
    #[inline]
    pub fn subtracted(&self, other: &VarStats) -> VarStats {
        let na = self.n - other.n;
        if na <= 0.0 {
            return VarStats::EMPTY;
        }
        if other.is_empty() {
            return *self;
        }
        let mean_a = (self.n * self.mean - other.n * other.mean) / na;
        let delta = other.mean - mean_a;
        let m2_a = self.m2 - other.m2 - delta * delta * (na * other.n / self.n);
        VarStats { n: na, mean: mean_a, m2: m2_a.max(0.0) }
    }
}

impl Add for VarStats {
    type Output = VarStats;
    #[inline]
    fn add(self, rhs: VarStats) -> VarStats {
        self.merged(&rhs)
    }
}

impl AddAssign for VarStats {
    #[inline]
    fn add_assign(&mut self, rhs: VarStats) {
        *self = self.merged(&rhs);
    }
}

impl Sub for VarStats {
    type Output = VarStats;
    #[inline]
    fn sub(self, rhs: VarStats) -> VarStats {
        self.subtracted(&rhs)
    }
}

impl SubAssign for VarStats {
    #[inline]
    fn sub_assign(&mut self, rhs: VarStats) {
        *self = self.subtracted(&rhs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::{check, expect_close};
    use crate::common::Rng;

    fn reference_var(ys: &[f64]) -> (f64, f64) {
        let n = ys.len() as f64;
        let mean = ys.iter().sum::<f64>() / n;
        let var = if ys.len() > 1 {
            ys.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / (n - 1.0)
        } else {
            0.0
        };
        (mean, var)
    }

    #[test]
    fn single_observation() {
        let s = VarStats::from_one(3.5, 1.0);
        assert_eq!((s.n, s.mean, s.m2), (1.0, 3.5, 0.0));
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn matches_two_pass_reference() {
        let ys = [1.0, 2.0, 4.0, 8.0, -3.0];
        let s = VarStats::from_slice(&ys);
        let (mean, var) = reference_var(&ys);
        assert!((s.mean - mean).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn weighted_equals_repeats() {
        let mut w = VarStats::new();
        w.update(5.0, 3.0);
        w.update(1.0, 2.0);
        let r = VarStats::from_slice(&[5.0, 5.0, 5.0, 1.0, 1.0]);
        assert!((w.mean - r.mean).abs() < 1e-12);
        assert!((w.m2 - r.m2).abs() < 1e-12);
    }

    #[test]
    fn zero_weight_ignored() {
        let mut s = VarStats::from_slice(&[1.0, 2.0]);
        let before = s;
        s.update(100.0, 0.0);
        assert_eq!(s, before);
    }

    #[test]
    fn cancellation_robustness() {
        // naive sum-of-squares would return variance 0 (or negative) here
        let offset = 1e9;
        let ys: Vec<f64> = [0.0, 0.1, 0.2, 0.3].iter().map(|v| v + offset).collect();
        let s = VarStats::from_slice(&ys);
        let (_, var) = reference_var(&ys);
        assert!((s.variance() - var).abs() / var < 1e-6, "{} vs {var}", s.variance());
    }

    #[test]
    fn merge_identity() {
        let s = VarStats::from_slice(&[1.0, 2.0]);
        assert_eq!(s + VarStats::EMPTY, s);
        assert_eq!(VarStats::EMPTY + s, s);
    }

    #[test]
    fn subtract_all_gives_empty() {
        let s = VarStats::from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(s - s, VarStats::EMPTY);
    }

    #[test]
    fn prop_merge_equals_concat() {
        check("merge==concat", 0xA0, 200, |rng| {
            let na = rng.below(50) as usize + 1;
            let nb = rng.below(50) as usize + 1;
            let a: Vec<f64> = (0..na).map(|_| rng.normal(0.0, 100.0)).collect();
            let b: Vec<f64> = (0..nb).map(|_| rng.normal(5.0, 1.0)).collect();
            let merged = VarStats::from_slice(&a) + VarStats::from_slice(&b);
            let all: Vec<f64> = a.iter().chain(b.iter()).copied().collect();
            let direct = VarStats::from_slice(&all);
            expect_close("n", merged.n, direct.n, 0.0, 0.0)?;
            expect_close("mean", merged.mean, direct.mean, 1e-10, 1e-10)?;
            expect_close("m2", merged.m2, direct.m2, 1e-8, 1e-8)
        });
    }

    #[test]
    fn prop_merge_associative() {
        check("merge-assoc", 0xA1, 200, |rng| {
            let mk = |rng: &mut Rng| {
                let n = rng.below(30) as usize + 1;
                VarStats::from_slice(&(0..n).map(|_| rng.normal(0.0, 10.0)).collect::<Vec<_>>())
            };
            let (a, b, c) = (mk(rng), mk(rng), mk(rng));
            let l = (a + b) + c;
            let r = a + (b + c);
            expect_close("mean", l.mean, r.mean, 1e-10, 1e-10)?;
            expect_close("m2", l.m2, r.m2, 1e-8, 1e-8)
        });
    }

    #[test]
    fn prop_subtract_inverts_merge() {
        check("sub-inverts-merge", 0xA2, 200, |rng| {
            let na = rng.below(40) as usize + 1;
            let nb = rng.below(40) as usize + 1;
            let a = VarStats::from_slice(&(0..na).map(|_| rng.normal(-3.0, 7.0)).collect::<Vec<_>>());
            let b = VarStats::from_slice(&(0..nb).map(|_| rng.normal(2.0, 0.5)).collect::<Vec<_>>());
            let rec = (a + b) - b;
            expect_close("n", rec.n, a.n, 0.0, 1e-12)?;
            expect_close("mean", rec.mean, a.mean, 1e-8, 1e-8)?;
            expect_close("m2", rec.m2, a.m2, 1e-6, 1e-6)
        });
    }

    #[test]
    fn prop_variance_non_negative() {
        check("var>=0", 0xA3, 200, |rng| {
            let n = rng.below(20) as usize + 2;
            let s = VarStats::from_slice(&(0..n).map(|_| rng.normal(0.0, 1e-9)).collect::<Vec<_>>());
            let t = s - VarStats::from_one(s.mean, 1.0);
            if s.variance() >= 0.0 && t.variance() >= 0.0 {
                Ok(())
            } else {
                Err(format!("negative variance {} {}", s.variance(), t.variance()))
            }
        });
    }
}
