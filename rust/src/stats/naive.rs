//! The *naive* incremental variance estimator (Σw, Σy, Σy²) that the
//! original E-BST used — kept for the paper's robustness ablation
//! (Sec. 3 motivates replacing it; `cargo bench --bench ablations`
//! demonstrates the catastrophic cancellation it suffers).

/// Naive sufficient statistics: Σw, Σwy, Σwy².
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NaiveVarStats {
    pub n: f64,
    pub sum: f64,
    pub sum_sq: f64,
}

impl NaiveVarStats {
    pub fn new() -> NaiveVarStats {
        NaiveVarStats::default()
    }

    #[inline]
    pub fn update(&mut self, y: f64, w: f64) {
        self.n += w;
        self.sum += w * y;
        self.sum_sq += w * y * y;
    }

    #[inline]
    pub fn mean(&self) -> f64 {
        if self.n > 0.0 {
            self.sum / self.n
        } else {
            0.0
        }
    }

    /// Sample variance via the (cancellation-prone) sum-of-squares formula.
    /// Deliberately NOT clamped: the ablation shows the negative values.
    #[inline]
    pub fn variance(&self) -> f64 {
        if self.n > 1.0 {
            (self.sum_sq - self.sum * self.sum / self.n) / (self.n - 1.0)
        } else {
            0.0
        }
    }

    #[inline]
    pub fn merged(&self, o: &NaiveVarStats) -> NaiveVarStats {
        NaiveVarStats { n: self.n + o.n, sum: self.sum + o.sum, sum_sq: self.sum_sq + o.sum_sq }
    }

    #[inline]
    pub fn subtracted(&self, o: &NaiveVarStats) -> NaiveVarStats {
        NaiveVarStats { n: self.n - o.n, sum: self.sum - o.sum, sum_sq: self.sum_sq - o.sum_sq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::VarStats;

    #[test]
    fn agrees_with_robust_on_benign_data() {
        let ys = [1.0, 2.0, 3.0, 4.0, 5.5];
        let mut naive = NaiveVarStats::new();
        let mut robust = VarStats::new();
        for &y in &ys {
            naive.update(y, 1.0);
            robust.update(y, 1.0);
        }
        assert!((naive.mean() - robust.mean).abs() < 1e-12);
        assert!((naive.variance() - robust.variance()).abs() < 1e-12);
    }

    #[test]
    fn cancellation_failure_demonstrated() {
        // Same case where VarStats stays accurate (welford.rs test):
        // offset 1e9, true variance ~0.0167 — the naive estimator's
        // relative error explodes by comparison.
        let offset = 1e9;
        let ys: Vec<f64> = [0.0, 0.1, 0.2, 0.3].iter().map(|v| v + offset).collect();
        let mut naive = NaiveVarStats::new();
        let mut robust = VarStats::new();
        for &y in &ys {
            naive.update(y, 1.0);
            robust.update(y, 1.0);
        }
        let truth = 0.016_666_666_666_666_666;
        let naive_err = (naive.variance() - truth).abs() / truth;
        let robust_err = (robust.variance() - truth).abs() / truth;
        assert!(naive_err > 100.0 * robust_err.max(1e-16), "naive={naive_err} robust={robust_err}");
    }

    #[test]
    fn merge_subtract_roundtrip() {
        let a = {
            let mut s = NaiveVarStats::new();
            s.update(1.0, 1.0);
            s.update(2.0, 1.0);
            s
        };
        let b = {
            let mut s = NaiveVarStats::new();
            s.update(7.0, 2.0);
            s
        };
        let rec = a.merged(&b).subtracted(&b);
        assert!((rec.n - a.n).abs() < 1e-12);
        assert!((rec.sum - a.sum).abs() < 1e-9);
    }
}
