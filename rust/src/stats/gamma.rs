//! Special functions needed by the hypothesis tests: log-gamma (Lanczos),
//! regularized incomplete gamma (series + continued fraction), and the
//! chi-square / F survival functions built on them.

/// Lanczos approximation of ln Γ(x) for x > 0 (|rel err| < 1e-13).
pub fn ln_gamma(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(s, x) = γ(s, x)/Γ(s).
pub fn gamma_p(s: f64, x: f64) -> f64 {
    assert!(s > 0.0);
    if x <= 0.0 {
        return 0.0;
    }
    if x < s + 1.0 {
        // series representation
        let mut term = 1.0 / s;
        let mut sum = term;
        let mut k = s;
        for _ in 0..500 {
            k += 1.0;
            term *= x / k;
            sum += term;
            if term.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        (sum.ln() + s * x.ln() - x - ln_gamma(s)).exp()
    } else {
        1.0 - gamma_q_cf(s, x)
    }
}

/// Regularized upper incomplete gamma Q(s, x) via Lentz continued fraction.
fn gamma_q_cf(s: f64, x: f64) -> f64 {
    let mut b = x + 1.0 - s;
    let mut c = 1.0 / 1e-300;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - s);
        b += 2.0;
        d = an * d + b;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        c = b + an / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (s * x.ln() - x - ln_gamma(s)).exp() * h
}

/// Chi-square survival function: P(X > x) with k degrees of freedom.
pub fn chi2_sf(x: f64, k: f64) -> f64 {
    if x <= 0.0 {
        return 1.0;
    }
    (1.0 - gamma_p(k / 2.0, x / 2.0)).clamp(0.0, 1.0)
}

/// Regularized incomplete beta I_x(a, b) (for the F distribution).
pub fn beta_inc(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // continued fraction (Lentz) — standard Numerical Recipes betacf
    let cf = |a: f64, b: f64, x: f64| -> f64 {
        let qab = a + b;
        let qap = a + 1.0;
        let qam = a - 1.0;
        let mut c = 1.0;
        let mut d = 1.0 - qab * x / qap;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        d = 1.0 / d;
        let mut h = d;
        for m in 1..300 {
            let m = m as f64;
            let m2 = 2.0 * m;
            let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
            d = 1.0 + aa * d;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = 1.0 + aa / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            h *= d * c;
            let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
            d = 1.0 + aa * d;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = 1.0 + aa / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-14 {
                break;
            }
        }
        h
    };
    if x < (a + 1.0) / (a + b + 2.0) {
        front * cf(a, b, x) / a
    } else {
        1.0 - (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + b * (1.0 - x).ln() + a * x.ln())
            .exp()
            * cf(b, a, 1.0 - x)
            / b
    }
}

/// F-distribution survival function P(F > f) with (d1, d2) dof.
pub fn f_sf(f: f64, d1: f64, d2: f64) -> f64 {
    if f <= 0.0 {
        return 1.0;
    }
    beta_inc(d2 / 2.0, d1 / 2.0, d2 / (d2 + d1 * f)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-12);
    }

    #[test]
    fn chi2_sf_known_values() {
        // scipy.stats.chi2.sf reference values
        assert!((chi2_sf(3.841, 1.0) - 0.05004).abs() < 1e-4);
        assert!((chi2_sf(9.488, 4.0) - 0.05002).abs() < 1e-4);
        assert!((chi2_sf(18.307, 10.0) - 0.05001).abs() < 1e-4);
        assert!((chi2_sf(0.0, 3.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gamma_p_limits() {
        assert_eq!(gamma_p(2.0, 0.0), 0.0);
        assert!((gamma_p(1.0, 30.0) - 1.0).abs() < 1e-10);
        // P(1, x) = 1 - e^-x
        assert!((gamma_p(1.0, 1.0) - (1.0 - (-1f64).exp())).abs() < 1e-12);
    }

    #[test]
    fn f_sf_known_values() {
        // scipy.stats.f.sf reference values
        assert!((f_sf(4.256, 4.0, 10.0) - 0.028_734).abs() < 1e-3);
        assert!((f_sf(1.0, 5.0, 5.0) - 0.5).abs() < 1e-10);
    }

    #[test]
    fn beta_inc_symmetry() {
        for &(a, b, x) in &[(2.0, 3.0, 0.4), (0.5, 0.5, 0.3), (5.0, 1.0, 0.9)] {
            let lhs = beta_inc(a, b, x);
            let rhs = 1.0 - beta_inc(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-10, "{a} {b} {x}: {lhs} vs {rhs}");
        }
    }
}
