//! `cargo bench --bench fig3_splitdiff` — regenerates the paper's Figure 3
//! (average |split − E-BST split| per observer vs sample size).

#![forbid(unsafe_code)]

use qostream::bench_suite::{fig3, Profile, Protocol};

fn main() {
    let protocol = Protocol::new(Profile::Quick);
    eprintln!("fig3_splitdiff: {}", protocol.describe());
    let rendered = fig3::generate(&protocol, true).expect("fig3");
    println!("{rendered}");
    println!("full data written to results/fig3/");
}
