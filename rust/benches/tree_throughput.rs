//! `cargo bench --bench tree_throughput` — the Sec. 7 integration bench:
//! Hoeffding trees with each observer on Friedman #1, reporting prequential
//! accuracy, throughput and stored elements.

use qostream::bench_suite::tree_bench;

fn main() {
    let rendered = tree_bench::generate(30_000, 1).expect("tree bench");
    println!("{rendered}");
    println!("full data written to results/tree/");
}
