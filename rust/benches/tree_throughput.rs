//! `cargo bench --bench tree_throughput` — the Sec. 7 integration bench:
//! Hoeffding trees with each observer on Friedman #1, reporting prequential
//! accuracy, throughput and stored elements — followed by the forest
//! scenario (single tree vs online bagging vs ARF, QO vs E-BST observers
//! inside the ensemble, on a drifting Friedman stream) and the
//! split-query backend comparison (per-observer vs batched paths on a
//! ≥ 10-member forest; bit-identical models, different wall-clock).

#![forbid(unsafe_code)]

use qostream::bench_suite::{forest_bench, tree_bench};

fn main() {
    let rendered = tree_bench::generate(30_000, 1).expect("tree bench");
    println!("{rendered}");
    println!("full data written to results/tree/");

    let cfg = forest_bench::ForestBenchConfig::default();
    let rendered = forest_bench::generate(&cfg).expect("forest bench");
    println!("{rendered}");
    println!("full data written to results/forest/");
    // (the forest summary above already includes the split-query backend
    // comparison line produced by forest_bench::backend_comparison)
}
