//! `cargo bench --bench cd_diagrams` — regenerates the paper's Figures 2,
//! 4, 5 and 6: Friedman tests + Nemenyi critical-difference diagrams over
//! the protocol grid for merit, elements, observation time and query time.

#![forbid(unsafe_code)]

use qostream::bench_suite::{cd, Profile, Protocol};

fn main() {
    let protocol = Protocol::new(Profile::Quick);
    eprintln!("cd_diagrams: {}", protocol.describe());
    let rendered = cd::generate(&protocol, true).expect("cd");
    println!("{rendered}");
    println!("full data written to results/cd/");
}
