//! `cargo bench --bench xla_vs_native` — stack-composition benchmark:
//! split-candidate evaluation through the AOT JAX/Pallas artifact on PJRT
//! vs the native rust query path, across slot counts and feature batches.
//!
//! Skips (with a message) when `artifacts/` is missing.

use qostream::common::timing::{bench, human_time};
use qostream::common::Rng;
use qostream::criterion::VarianceReduction;
use qostream::observer::{AttributeObserver, QuantizationObserver};
use qostream::runtime::{find_artifacts_dir, Manifest, SlotTable, XlaSplitEngine};

fn observers_with_slots(target_slots: usize, n_obs: usize) -> Vec<QuantizationObserver> {
    // radius tuned so a N(0,1) sample lands in ~target_slots buckets
    let radius = 6.0 / target_slots as f64;
    let mut rng = Rng::new(11);
    (0..n_obs)
        .map(|_| {
            let mut qo = QuantizationObserver::with_radius(radius);
            for _ in 0..20_000 {
                let x = rng.normal(0.0, 1.0);
                qo.observe(x, x * x + rng.normal(0.0, 0.1), 1.0);
            }
            qo
        })
        .collect()
}

fn main() {
    let Ok(dir) = find_artifacts_dir() else {
        println!("xla_vs_native: artifacts/ missing — run `make artifacts` first (skipped)");
        return;
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let client = xla::PjRtClient::cpu().expect("pjrt");
    let engine = XlaSplitEngine::load(&client, &manifest).expect("engine");
    println!("engine F={} S={}\n", engine.f, engine.s);
    println!(
        "{:>8} {:>10} {:>14} {:>14} {:>10}",
        "slots", "features", "xla/call", "native/call", "xla/native"
    );
    for &slots in &[16usize, 64, 200] {
        let observers = observers_with_slots(slots, engine.f);
        let tables: Vec<SlotTable> = observers.iter().map(SlotTable::from_qo).collect();
        let actual_slots = tables[0].len();

        let xla_stats = bench(3, 30, || engine.best_splits(&tables).unwrap());
        let native_stats = bench(3, 30, || {
            observers
                .iter()
                .map(|qo| qo.best_split(&VarianceReduction))
                .collect::<Vec<_>>()
        });
        println!(
            "{:>8} {:>10} {:>14} {:>14} {:>9.1}x",
            actual_slots,
            engine.f,
            human_time(xla_stats.mean),
            human_time(native_stats.mean),
            xla_stats.mean / native_stats.mean
        );

        // correctness spot-check on every run
        let xla_res = engine.best_splits(&tables).unwrap();
        for (qo, res) in observers.iter().zip(&xla_res) {
            let native = qo.best_split(&VarianceReduction).unwrap();
            assert!((res.unwrap().threshold - native.threshold).abs() < 1e-9);
        }
    }
    println!("\n(the XLA path amortizes across the feature batch; the native path");
    println!(" wins on tiny tables — crossover analysis in EXPERIMENTS.md)");
}
