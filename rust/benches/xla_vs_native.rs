//! `cargo bench --bench xla_vs_native` — stack-composition benchmark for
//! the split-query backends:
//!
//! 1. per-observer queries vs the flat-packed [`NativeBatchBackend`]
//!    (always runs — both are pure rust and bit-identical);
//! 2. the AOT JAX/Pallas artifact on PJRT vs the native query path,
//!    across slot counts and feature batches (skips with a message when
//!    `artifacts/` or the runtime is missing).

#![forbid(unsafe_code)]

use qostream::common::timing::{bench, human_time};
use qostream::common::Rng;
use qostream::criterion::VarianceReduction;
use qostream::observer::{AttributeObserver, QuantizationObserver};
use qostream::runtime::{
    find_artifacts_dir, Manifest, NativeBatchBackend, PerObserverBackend, SlotTable,
    SplitBackend, SplitQuery, XlaSplitEngine,
};

fn observers_with_slots(target_slots: usize, n_obs: usize) -> Vec<QuantizationObserver> {
    // radius tuned so a N(0,1) sample lands in ~target_slots buckets
    let radius = 6.0 / target_slots as f64;
    let mut rng = Rng::new(11);
    (0..n_obs)
        .map(|_| {
            let mut qo = QuantizationObserver::with_radius(radius);
            for _ in 0..20_000 {
                let x = rng.normal(0.0, 1.0);
                qo.observe(x, x * x + rng.normal(0.0, 0.1), 1.0);
            }
            qo
        })
        .collect()
}

fn native_backend_section() {
    println!("== native split-query backends (per-observer vs flat batch) ==");
    println!(
        "{:>8} {:>10} {:>14} {:>14} {:>12}",
        "slots", "features", "batch/call", "loop/call", "batch/loop"
    );
    let criterion = VarianceReduction;
    for &slots in &[16usize, 64, 200] {
        let observers = observers_with_slots(slots, 16);
        let queries: Vec<SplitQuery<'_>> = observers
            .iter()
            .map(|qo| SplitQuery { observer: qo as &dyn AttributeObserver, criterion: &criterion })
            .collect();
        let actual_slots = observers[0].n_elements();

        let batch_stats = bench(3, 30, || NativeBatchBackend.best_splits(&queries));
        let loop_stats = bench(3, 30, || PerObserverBackend.best_splits(&queries));
        println!(
            "{:>8} {:>10} {:>14} {:>14} {:>11.2}x",
            actual_slots,
            queries.len(),
            human_time(batch_stats.mean),
            human_time(loop_stats.mean),
            batch_stats.mean / loop_stats.mean
        );

        // bit-identity spot-check on every run
        let batched = NativeBatchBackend.best_splits(&queries);
        let looped = PerObserverBackend.best_splits(&queries);
        for (b, l) in batched.iter().zip(&looped) {
            let (b, l) = (b.expect("split"), l.expect("split"));
            assert_eq!(b.threshold.to_bits(), l.threshold.to_bits());
            assert_eq!(b.merit.to_bits(), l.merit.to_bits());
        }
    }
    println!();
}

fn main() {
    native_backend_section();

    let Ok(dir) = find_artifacts_dir() else {
        println!("xla_vs_native: artifacts/ missing — run `make artifacts` first (xla section skipped)");
        return;
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let client = match xla::PjRtClient::cpu() {
        Ok(client) => client,
        Err(err) => {
            println!("xla_vs_native: PJRT unavailable ({err}) — xla section skipped");
            return;
        }
    };
    let engine = XlaSplitEngine::load(&client, &manifest).expect("engine");
    println!("engine F={} S={}\n", engine.f, engine.s);
    println!(
        "{:>8} {:>10} {:>14} {:>14} {:>10}",
        "slots", "features", "xla/call", "native/call", "xla/native"
    );
    for &slots in &[16usize, 64, 200] {
        let observers = observers_with_slots(slots, engine.f);
        let tables: Vec<SlotTable> = observers.iter().map(SlotTable::from_qo).collect();
        let actual_slots = tables[0].len();

        let xla_stats = bench(3, 30, || engine.best_splits(&tables).unwrap());
        let native_stats = bench(3, 30, || {
            observers
                .iter()
                .map(|qo| qo.best_split(&VarianceReduction))
                .collect::<Vec<_>>()
        });
        println!(
            "{:>8} {:>10} {:>14} {:>14} {:>9.1}x",
            actual_slots,
            engine.f,
            human_time(xla_stats.mean),
            human_time(native_stats.mean),
            xla_stats.mean / native_stats.mean
        );

        // correctness spot-check on every run
        let xla_res = engine.best_splits(&tables).unwrap();
        for (qo, res) in observers.iter().zip(&xla_res) {
            let native = qo.best_split(&VarianceReduction).unwrap();
            assert!((res.unwrap().threshold - native.threshold).abs() < 1e-9);
        }
    }
    println!("\n(the XLA path amortizes across the feature batch; the native path");
    println!(" wins on tiny tables — crossover analysis in EXPERIMENTS.md)");
}
