//! `cargo bench --bench ablations` — the design-choice ablations DESIGN.md
//! calls out:
//!
//! 1. **Radius sweep** — the merit/memory/time trade-off as r varies
//!    (paper Sec. 6.1: "the smaller the radius, the higher the merit;
//!    the larger the radius, the smaller the runtime and memory").
//! 2. **Robust vs naive variance** — the Sec. 3 motivation: catastrophic
//!    cancellation of the Σy² estimator under a large target offset.
//! 3. **Insertion-cost crossover** — observe-time per element for QO
//!    (O(1)) vs E-BST (O(log n)) as the sample grows.

#![forbid(unsafe_code)]

use qostream::common::table::{fnum, Table};
use qostream::common::timing::human_time;
use qostream::common::Rng;
use qostream::criterion::VarianceReduction;
use qostream::observer::{AttributeObserver, EBst, QuantizationObserver};
use qostream::stats::{NaiveVarStats, VarStats};
use std::time::Instant;

fn radius_sweep() {
    println!("== ablation 1: quantization radius sweep (N(0,1) feature, y = x^3, n=100k) ==");
    let mut rng = Rng::new(1);
    let sample: Vec<(f64, f64)> = (0..100_000)
        .map(|_| {
            let x = rng.normal(0.0, 1.0);
            (x, x * x * x + rng.normal(0.0, 0.05))
        })
        .collect();
    // exhaustive merit for reference
    let mut ebst = EBst::new();
    for &(x, y) in &sample {
        ebst.observe(x, y, 1.0);
    }
    let merit_ref = ebst.best_split(&VarianceReduction).unwrap().merit;

    let mut table =
        Table::new(vec!["radius", "slots", "merit", "merit/exact", "observe", "query"]);
    for &r in &[2.0, 1.0, 0.5, 0.25, 0.1, 0.05, 0.01, 0.005, 0.001] {
        let mut qo = QuantizationObserver::with_radius(r);
        let t0 = Instant::now();
        for &(x, y) in &sample {
            qo.observe(x, y, 1.0);
        }
        let observe = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let split = qo.best_split(&VarianceReduction).unwrap();
        let query = t0.elapsed().as_secs_f64();
        table.row(vec![
            format!("{r}"),
            qo.n_elements().to_string(),
            fnum(split.merit),
            format!("{:.4}", split.merit / merit_ref),
            human_time(observe),
            human_time(query),
        ]);
    }
    println!("{}", table.render());
}

fn variance_robustness() {
    println!("== ablation 2: robust (Welford/Chan) vs naive (sum-of-squares) variance ==");
    let mut table = Table::new(vec!["offset", "true var", "robust err", "naive err"]);
    let mut rng = Rng::new(2);
    for &offset in &[0.0, 1e3, 1e6, 1e8, 1e9] {
        let ys: Vec<f64> = (0..10_000).map(|_| offset + rng.normal(0.0, 0.1)).collect();
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        let true_var =
            ys.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / (ys.len() - 1) as f64;
        let mut robust = VarStats::new();
        let mut naive = NaiveVarStats::new();
        for &y in &ys {
            robust.update(y, 1.0);
            naive.update(y, 1.0);
        }
        let rerr = (robust.variance() - true_var).abs() / true_var;
        let nerr = (naive.variance() - true_var).abs() / true_var;
        table.row(vec![
            format!("{offset:.0e}"),
            fnum(true_var),
            format!("{rerr:.2e}"),
            format!("{nerr:.2e}"),
        ]);
    }
    println!("{}", table.render());
    println!("(the naive estimator the original E-BST used loses ALL precision at 1e8+;\n the Sec. 3 robust estimators hold at ~1e-9 relative error)\n");
}

fn insertion_crossover() {
    println!("== ablation 3: per-element observation cost, QO O(1) vs E-BST O(log n) ==");
    let mut table = Table::new(vec!["n", "QO ns/insert", "E-BST ns/insert", "ratio"]);
    for &n in &[1_000usize, 10_000, 100_000, 1_000_000] {
        let mut rng = Rng::new(3);
        let sample: Vec<(f64, f64)> =
            (0..n).map(|_| (rng.normal(0.0, 1.0), rng.normal(0.0, 1.0))).collect();
        let mut qo = QuantizationObserver::with_radius(0.05);
        let t0 = Instant::now();
        for &(x, y) in &sample {
            qo.observe(x, y, 1.0);
        }
        let qo_ns = t0.elapsed().as_secs_f64() * 1e9 / n as f64;
        let mut ebst = EBst::new();
        let t0 = Instant::now();
        for &(x, y) in &sample {
            ebst.observe(x, y, 1.0);
        }
        let ebst_ns = t0.elapsed().as_secs_f64() * 1e9 / n as f64;
        table.row(vec![
            n.to_string(),
            format!("{qo_ns:.1}"),
            format!("{ebst_ns:.1}"),
            format!("{:.2}x", ebst_ns / qo_ns),
        ]);
    }
    println!("{}", table.render());
    println!("(QO's per-insert cost is flat; E-BST's grows with log n — the paper's\n headline complexity claim, measured)\n");
}

fn split_strategy() {
    use qostream::observer::qo::SplitPointStrategy;
    use qostream::observer::ExhaustiveObserver;
    println!("== ablation 4: split-point strategy (prototype midpoint vs grid boundary) ==");
    println!("(paper Sec. 4: 'other strategies could also be employed')");
    let mut table = Table::new(vec!["radius", "|proto - exact|", "|grid - exact|"]);
    let mut rng = Rng::new(4);
    let sample: Vec<(f64, f64)> = (0..50_000)
        .map(|_| {
            let x = rng.normal(0.0, 1.0);
            (x, x * x * x + rng.normal(0.0, 0.05))
        })
        .collect();
    let mut oracle = ExhaustiveObserver::new();
    for &(x, y) in &sample {
        oracle.observe(x, y, 1.0);
    }
    let exact = oracle.best_split(&VarianceReduction).unwrap().threshold;
    for &r in &[0.5, 0.1, 0.02] {
        let mut proto = QuantizationObserver::with_radius(r);
        let mut grid = QuantizationObserver::with_radius(r)
            .with_strategy(SplitPointStrategy::GridBoundary);
        for &(x, y) in &sample {
            proto.observe(x, y, 1.0);
            grid.observe(x, y, 1.0);
        }
        let tp = proto.best_split(&VarianceReduction).unwrap().threshold;
        let tg = grid.best_split(&VarianceReduction).unwrap().threshold;
        table.row(vec![
            format!("{r}"),
            format!("{:.5}", (tp - exact).abs()),
            format!("{:.5}", (tg - exact).abs()),
        ]);
    }
    println!("{}", table.render());
    println!("(prototype midpoints track the data inside the bucket; grid boundaries\n are data-independent — the accuracy gap is why the paper pays for sum_x)\n");
}

fn main() {
    radius_sweep();
    variance_robustness();
    insertion_crossover();
    split_strategy();
}
