//! `cargo bench --bench fig1_sweep` — regenerates the paper's Figure 1
//! (VR, stored elements, observation time, query time vs sample size) on
//! the quick profile. Use the CLI (`qostream fig1 --profile standard|full`)
//! for the larger grids.

#![forbid(unsafe_code)]

use qostream::bench_suite::{fig1, Profile, Protocol};

fn main() {
    let protocol = Protocol::new(Profile::Quick);
    eprintln!("fig1_sweep: {}", protocol.describe());
    let rendered = fig1::generate(&protocol, true).expect("fig1");
    println!("{rendered}");
    println!("full data written to results/fig1/");
}
